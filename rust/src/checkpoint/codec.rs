//! Pluggable per-chunk codec stage for the checkpoint write path.
//!
//! FastPersist makes every written byte cheaper (parallel writers,
//! O_DIRECT drains, ring submission); the complementary lever — the one
//! Check-N-Run (arXiv:2010.08679) reports ~17x from — is writing fewer
//! bytes. This module supplies that stage: dirty chunks are encoded
//! **between serialization and segment packing**, so the
//! [`crate::checkpoint::plan::WritePlan`] / drain-lane / ring mechanics
//! below stay byte-oriented and untouched — they see opaque payloads of
//! whatever length the codec produced.
//!
//! Three codecs:
//!
//! * [`CodecKind::None`] — identity; the chunk's raw bytes are stored.
//! * [`CodecKind::Lz4`] — LZ77-style block compression in the spirit of
//!   the LZ4 block format (greedy hash-chain matching, 4-bit
//!   literal/match length nibbles with 255-run extensions, 16-bit match
//!   offsets), implemented entirely in-repo so no dependency is added.
//! * [`CodecKind::QuantDelta`] — a *quantized delta*: the wrapping
//!   byte-difference against the chunk's **base** (the most recent
//!   raw-stored version of the same chunk index) is stored as zero-runs
//!   plus 4-bit-packed small diffs, with a raw-literal escape for bytes
//!   whose diff does not quantize. Decoding is **exact** — the escape
//!   op preserves full precision — so restores are always bit-identical
//!   and chain compaction (which rewrites raw bytes) guarantees no
//!   representation ever feeds a *second* level of quantization: diffs
//!   are depth-1 against a raw base by construction.
//!
//! Every codec is lossless after decode. The manifest keeps the **raw**
//! chunk hash, and the read path verifies the *decoded* bytes against
//! it, so a corrupted encoded stream either fails the decoder's own
//! fail-closed checks or trips the existing hash verification — garbage
//! bytes are never handed to the caller.

use crate::{Error, Result};

/// Which codec encoded a chunk's stored bytes. The `u8` values are the
/// on-disk codec ids in the manifest v6 chunk table — append-only,
/// never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum CodecKind {
    /// Identity: stored bytes are the chunk's raw bytes.
    #[default]
    None = 0,
    /// In-repo LZ77 block compression ([`lz4_compress`]).
    Lz4 = 1,
    /// Quantized delta against the chunk's raw base ([`qdelta_encode`]).
    QuantDelta = 2,
}

impl CodecKind {
    /// CLI / manifest-facing name.
    pub fn name(self) -> &'static str {
        match self {
            CodecKind::None => "none",
            CodecKind::Lz4 => "lz4",
            CodecKind::QuantDelta => "qdelta",
        }
    }

    /// Parse a CLI spelling (`none` / `lz4` / `qdelta`).
    pub fn parse(s: &str) -> Result<CodecKind> {
        match s {
            "none" => Ok(CodecKind::None),
            "lz4" => Ok(CodecKind::Lz4),
            "qdelta" => Ok(CodecKind::QuantDelta),
            other => Err(Error::Config(format!(
                "unknown checkpoint codec {other:?} (expected none|lz4|qdelta)"
            ))),
        }
    }

    /// On-disk codec id (manifest v6 chunk record byte 36).
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Inverse of [`CodecKind::as_u8`], fail-closed on unknown ids.
    pub fn from_u8(b: u8) -> Result<CodecKind> {
        match b {
            0 => Ok(CodecKind::None),
            1 => Ok(CodecKind::Lz4),
            2 => Ok(CodecKind::QuantDelta),
            other => Err(Error::Format(format!("unknown codec id {other}"))),
        }
    }
}

impl std::fmt::Display for CodecKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Encode one chunk under `kind`. `base` is the chunk's raw base bytes
/// and is required (same length as `raw`) for [`CodecKind::QuantDelta`].
/// Returns the encoded payload; callers apply their own benefit gate
/// (store raw when the encoding didn't shrink).
pub fn encode_chunk(kind: CodecKind, raw: &[u8], base: Option<&[u8]>) -> Result<Vec<u8>> {
    match kind {
        CodecKind::None => Ok(raw.to_vec()),
        CodecKind::Lz4 => Ok(lz4_compress(raw)),
        CodecKind::QuantDelta => {
            let base = base.ok_or_else(|| {
                Error::Format("qdelta encode requires a base chunk".into())
            })?;
            qdelta_encode(raw, base)
        }
    }
}

/// Decode one chunk's encoded payload into `dest` (whose length is the
/// chunk's raw length). Fail-closed: truncated or malformed streams,
/// output over- or underrun, and missing bases yield a typed error —
/// never a panic, never a partially-filled `dest` reported as success.
pub fn decode_chunk_into(
    kind: CodecKind,
    enc: &[u8],
    base: Option<&[u8]>,
    dest: &mut [u8],
) -> Result<()> {
    match kind {
        CodecKind::None => {
            if enc.len() != dest.len() {
                return Err(Error::Format(format!(
                    "codec none: stored {} bytes for a {}-byte chunk",
                    enc.len(),
                    dest.len()
                )));
            }
            dest.copy_from_slice(enc);
            Ok(())
        }
        CodecKind::Lz4 => lz4_decompress_into(enc, dest),
        CodecKind::QuantDelta => {
            let base = base.ok_or_else(|| {
                Error::Format("qdelta decode requires the base chunk bytes".into())
            })?;
            qdelta_decode_into(enc, base, dest)
        }
    }
}

// ---------------------------------------------------------------------
// LZ77 block codec
// ---------------------------------------------------------------------

/// Hash-table size for match finding (2^13 entries ≈ 32 KiB of u32s).
const LZ_HASH_BITS: u32 = 13;
/// Minimum match length worth a copy token.
const LZ_MIN_MATCH: usize = 4;
/// Maximum back-reference distance (16-bit offset field).
const LZ_MAX_OFFSET: usize = 0xffff;

fn lz_hash(word: u32) -> usize {
    (word.wrapping_mul(2654435761) >> (32 - LZ_HASH_BITS)) as usize
}

fn lz_word(src: &[u8], i: usize) -> u32 {
    u32::from_le_bytes(src[i..i + 4].try_into().unwrap())
}

/// Append `n` as a 255-run extension (LZ4 style): bytes of 255 summing
/// toward `n`, terminated by the final byte < 255.
fn push_run(out: &mut Vec<u8>, mut n: usize) {
    while n >= 255 {
        out.push(255);
        n -= 255;
    }
    out.push(n as u8);
}

fn emit_sequence(out: &mut Vec<u8>, literals: &[u8], m: Option<(usize, usize)>) {
    let lit_code = literals.len().min(15);
    // match code 0 is reserved for the terminal literals-only sequence;
    // real matches are ≥ LZ_MIN_MATCH so their code is ≥ 1.
    let match_code = m.map_or(0, |(_, len)| (len - (LZ_MIN_MATCH - 1)).min(15));
    out.push(((lit_code as u8) << 4) | match_code as u8);
    if literals.len() >= 15 {
        push_run(out, literals.len() - 15);
    }
    out.extend_from_slice(literals);
    if let Some((offset, len)) = m {
        out.extend_from_slice(&(offset as u16).to_le_bytes());
        if len - (LZ_MIN_MATCH - 1) >= 15 {
            push_run(out, len - (LZ_MIN_MATCH - 1) - 15);
        }
    }
}

/// Greedy LZ77 block compression: single pass, one hash-table probe per
/// position, matches ≥ [`LZ_MIN_MATCH`] bytes within a
/// [`LZ_MAX_OFFSET`] window. Output grows at most a few bytes past the
/// input for incompressible data (callers gate on size).
pub fn lz4_compress(src: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 2 + 16);
    // table stores position + 1 so 0 means "empty"
    let mut table = vec![0usize; 1 << LZ_HASH_BITS];
    let mut i = 0usize;
    let mut anchor = 0usize;
    while i + LZ_MIN_MATCH <= src.len() {
        let h = lz_hash(lz_word(src, i));
        let cand = table[h];
        table[h] = i + 1;
        if cand > 0 {
            let c = cand - 1;
            if i - c <= LZ_MAX_OFFSET && lz_word(src, c) == lz_word(src, i) {
                let mut len = LZ_MIN_MATCH;
                while i + len < src.len() && src[c + len] == src[i + len] {
                    len += 1;
                }
                emit_sequence(&mut out, &src[anchor..i], Some((i - c, len)));
                i += len;
                anchor = i;
                continue;
            }
        }
        i += 1;
    }
    emit_sequence(&mut out, &src[anchor..], None);
    out
}

/// Decode an [`lz4_compress`] stream into `dest`, which must be exactly
/// the raw length. Every read and write is bounds-checked; malformed
/// input (truncation, zero or out-of-window offsets, output overrun or
/// underrun, trailing bytes) yields a typed error.
pub fn lz4_decompress_into(src: &[u8], dest: &mut [u8]) -> Result<()> {
    let fail = |d: String| Error::Format(format!("lz4 chunk: {d}"));
    let read_run = |src: &[u8], i: &mut usize, mut n: usize| -> Result<usize> {
        loop {
            let b = *src.get(*i).ok_or_else(|| fail("truncated length run".into()))?;
            *i += 1;
            n = n
                .checked_add(b as usize)
                .ok_or_else(|| fail("length run overflows".into()))?;
            if b < 255 {
                return Ok(n);
            }
        }
    };
    let mut i = 0usize;
    let mut o = 0usize;
    loop {
        let token = *src.get(i).ok_or_else(|| fail("truncated at token".into()))?;
        i += 1;
        let mut lit = (token >> 4) as usize;
        if lit == 15 {
            lit = read_run(src, &mut i, lit)?;
        }
        if !i.checked_add(lit).is_some_and(|e| e <= src.len()) {
            return Err(fail(format!("literal run of {lit} bytes is truncated")));
        }
        if !o.checked_add(lit).is_some_and(|e| e <= dest.len()) {
            return Err(fail(format!(
                "literals overrun output ({} of {} bytes filled)",
                o,
                dest.len()
            )));
        }
        dest[o..o + lit].copy_from_slice(&src[i..i + lit]);
        i += lit;
        o += lit;
        let match_code = (token & 0x0f) as usize;
        if match_code == 0 {
            // terminal sequence: all input and all output must be used
            if i != src.len() {
                return Err(fail(format!("{} trailing bytes after terminal", src.len() - i)));
            }
            if o != dest.len() {
                return Err(fail(format!("decoded {o} of {} bytes", dest.len())));
            }
            return Ok(());
        }
        if i + 2 > src.len() {
            return Err(fail("truncated at match offset".into()));
        }
        let offset = u16::from_le_bytes(src[i..i + 2].try_into().unwrap()) as usize;
        i += 2;
        let mut mlen = match_code + (LZ_MIN_MATCH - 1);
        if match_code == 15 {
            mlen = read_run(src, &mut i, mlen)?;
        }
        if offset == 0 || offset > o {
            return Err(fail(format!("match offset {offset} outside {o} produced bytes")));
        }
        if !o.checked_add(mlen).is_some_and(|e| e <= dest.len()) {
            return Err(fail(format!(
                "match of {mlen} bytes overruns output at {o}/{}",
                dest.len()
            )));
        }
        // byte-at-a-time: overlapping copies (offset < mlen) are the
        // codec's run-length encoding and must see freshly-written bytes
        for k in 0..mlen {
            dest[o + k] = dest[o + k - offset];
        }
        o += mlen;
    }
}

// ---------------------------------------------------------------------
// Quantized delta codec
// ---------------------------------------------------------------------

/// qdelta op: `n` diff bytes are zero (chunk equals base here).
const QD_ZERO: u8 = 0x00;
/// qdelta op: `n` diffs quantized to 4-bit two's complement (−8..=7).
const QD_NIBBLE: u8 = 0x01;
/// qdelta op: `n` raw chunk bytes verbatim — the full-precision escape
/// that keeps the codec exact.
const QD_RAW: u8 = 0x02;

/// Zero-runs shorter than this ride inside whatever op surrounds them.
const QD_MIN_ZERO_RUN: usize = 4;
/// Nibble runs shorter than this are not worth the op header.
const QD_MIN_NIBBLE_RUN: usize = 8;

fn push_varint(out: &mut Vec<u8>, mut n: u64) {
    loop {
        let b = (n & 0x7f) as u8;
        n >>= 7;
        if n == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(src: &[u8], i: &mut usize) -> Result<u64> {
    let mut n = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *src
            .get(*i)
            .ok_or_else(|| Error::Format("qdelta chunk: truncated varint".into()))?;
        *i += 1;
        if shift >= 63 && b > 1 {
            return Err(Error::Format("qdelta chunk: varint overflows u64".into()));
        }
        n |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(n);
        }
        shift += 7;
    }
}

/// True when the wrapping diff, read as a signed byte, fits a 4-bit
/// two's-complement nibble (−8..=7).
fn nibble_fits(d: u8) -> bool {
    (-8..=7).contains(&(d as i8))
}

/// Encode `raw` as a quantized delta against `base` (same length).
/// Layout: a sequence of `(op, varint n, payload)` records — zero runs
/// carry no payload, nibble runs carry `ceil(n/2)` packed bytes, raw
/// escapes carry `n` literal chunk bytes. Decoding is exact.
pub fn qdelta_encode(raw: &[u8], base: &[u8]) -> Result<Vec<u8>> {
    if raw.len() != base.len() {
        return Err(Error::Format(format!(
            "qdelta encode: chunk is {} bytes but base is {}",
            raw.len(),
            base.len()
        )));
    }
    let diff = |i: usize| raw[i].wrapping_sub(base[i]);
    let mut out = Vec::with_capacity(raw.len() / 8 + 16);
    let mut i = 0usize;
    let mut raw_start = 0usize; // pending raw-escape run [raw_start, i)
    let flush_raw = |out: &mut Vec<u8>, start: usize, end: usize| {
        if end > start {
            out.push(QD_RAW);
            push_varint(out, (end - start) as u64);
            out.extend_from_slice(&raw[start..end]);
        }
    };
    while i < raw.len() {
        // zero run?
        let mut z = i;
        while z < raw.len() && diff(z) == 0 {
            z += 1;
        }
        if z - i >= QD_MIN_ZERO_RUN {
            flush_raw(&mut out, raw_start, i);
            out.push(QD_ZERO);
            push_varint(&mut out, (z - i) as u64);
            i = z;
            raw_start = i;
            continue;
        }
        // nibble run? (small zero runs are nibble-representable and ride
        // along; a long zero run ends the nibble scan so it gets its own
        // cheaper op)
        let mut n = i;
        while n < raw.len() && nibble_fits(diff(n)) {
            if diff(n) == 0 {
                let mut z2 = n;
                while z2 < raw.len() && diff(z2) == 0 {
                    z2 += 1;
                }
                if z2 - n >= QD_MIN_ZERO_RUN {
                    break;
                }
                n = z2;
            } else {
                n += 1;
            }
        }
        if n - i >= QD_MIN_NIBBLE_RUN {
            flush_raw(&mut out, raw_start, i);
            let count = n - i;
            out.push(QD_NIBBLE);
            push_varint(&mut out, count as u64);
            let mut byte = 0u8;
            for (k, pos) in (i..n).enumerate() {
                let nib = (diff(pos) as i8 as u8) & 0x0f;
                if k % 2 == 0 {
                    byte = nib;
                } else {
                    out.push(byte | (nib << 4));
                }
            }
            if count % 2 == 1 {
                out.push(byte);
            }
            i = n;
            raw_start = i;
            continue;
        }
        // neither: this byte joins the pending raw escape
        i += 1;
    }
    flush_raw(&mut out, raw_start, i);
    Ok(out)
}

/// Decode a [`qdelta_encode`] stream into `dest` using `base` (both the
/// chunk's raw length). Fail-closed like [`lz4_decompress_into`].
pub fn qdelta_decode_into(enc: &[u8], base: &[u8], dest: &mut [u8]) -> Result<()> {
    let fail = |d: String| Error::Format(format!("qdelta chunk: {d}"));
    if base.len() != dest.len() {
        return Err(fail(format!(
            "base is {} bytes but chunk is {}",
            base.len(),
            dest.len()
        )));
    }
    let mut i = 0usize;
    let mut o = 0usize;
    while i < enc.len() {
        let op = enc[i];
        i += 1;
        let n = read_varint(enc, &mut i)? as usize;
        let in_bounds = o.checked_add(n).is_some_and(|end| end <= dest.len());
        if !in_bounds {
            return Err(fail(format!(
                "op {op:#04x} of {n} bytes overruns output at {o}/{}",
                dest.len()
            )));
        }
        match op {
            QD_ZERO => dest[o..o + n].copy_from_slice(&base[o..o + n]),
            QD_NIBBLE => {
                let nbytes = n.div_ceil(2);
                if i + nbytes > enc.len() {
                    return Err(fail("truncated nibble run".into()));
                }
                for k in 0..n {
                    let byte = enc[i + k / 2];
                    let nib = if k % 2 == 0 { byte & 0x0f } else { byte >> 4 };
                    // sign-extend the 4-bit two's-complement value
                    let v = ((nib << 4) as i8) >> 4;
                    dest[o + k] = base[o + k].wrapping_add(v as u8);
                }
                i += nbytes;
            }
            QD_RAW => {
                if i + n > enc.len() {
                    return Err(fail("truncated raw escape".into()));
                }
                dest[o..o + n].copy_from_slice(&enc[i..i + n]);
                i += n;
            }
            other => return Err(fail(format!("unknown op {other:#04x}"))),
        }
        o += n;
    }
    if o != dest.len() {
        return Err(fail(format!("decoded {o} of {} bytes", dest.len())));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift64* for reproducible payloads.
    struct Rng(u64);
    impl Rng {
        fn new(seed: u64) -> Rng {
            Rng(seed.max(1))
        }
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            self.0 = x;
            x.wrapping_mul(0x2545_f491_4f6c_dd1d)
        }
        fn bytes(&mut self, n: usize) -> Vec<u8> {
            (0..n).map(|_| self.next() as u8).collect()
        }
    }

    /// Structured, compressible payload: long runs + periodic pattern.
    fn structured(n: usize) -> Vec<u8> {
        (0..n).map(|i| ((i >> 6) as u8).wrapping_mul(31)).collect()
    }

    #[test]
    fn kind_names_ids_roundtrip() {
        for kind in [CodecKind::None, CodecKind::Lz4, CodecKind::QuantDelta] {
            assert_eq!(CodecKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(CodecKind::from_u8(kind.as_u8()).unwrap(), kind);
        }
        assert!(CodecKind::parse("gzip").is_err());
        assert!(CodecKind::from_u8(3).is_err());
        assert!(CodecKind::from_u8(255).is_err());
    }

    #[test]
    fn lz4_roundtrips_structured_random_and_edge_sizes() {
        let mut rng = Rng::new(7);
        let mut cases = vec![
            Vec::new(),
            vec![0u8],
            vec![7u8; 3],
            vec![42u8; 4096],
            structured(8192),
            structured(100_003),
        ];
        for n in [1usize, 4, 15, 16, 17, 255, 4096, 70_000] {
            cases.push(rng.bytes(n));
        }
        for raw in cases {
            let enc = lz4_compress(&raw);
            let mut dec = vec![0u8; raw.len()];
            lz4_decompress_into(&enc, &mut dec).unwrap();
            assert_eq!(dec, raw, "lz4 roundtrip failed for {} bytes", raw.len());
        }
    }

    #[test]
    fn lz4_compresses_structured_data() {
        let raw = structured(65_536);
        let enc = lz4_compress(&raw);
        assert!(
            enc.len() * 4 < raw.len(),
            "structured payload should compress ≥4x, got {} / {}",
            enc.len(),
            raw.len()
        );
    }

    #[test]
    fn lz4_decode_fails_closed_on_malformed_input() {
        let raw = structured(4096);
        let enc = lz4_compress(&raw);
        let mut dest = vec![0u8; raw.len()];
        // truncations at every prefix must error or (never) panic
        for cut in 0..enc.len().min(64) {
            assert!(
                lz4_decompress_into(&enc[..cut], &mut dest).is_err(),
                "truncated stream (len {cut}) must fail"
            );
        }
        // wrong output size: both directions fail
        let mut small = vec![0u8; raw.len() - 1];
        assert!(lz4_decompress_into(&enc, &mut small).is_err());
        let mut big = vec![0u8; raw.len() + 1];
        assert!(lz4_decompress_into(&enc, &mut big).is_err());
        // trailing garbage after the terminal sequence
        let mut trailing = enc.clone();
        trailing.push(0);
        assert!(lz4_decompress_into(&trailing, &mut dest).is_err());
        // a zero match offset is invalid (no bytes produced yet)
        let bad = vec![0x01u8, 0x00, 0x00]; // 0 literals, match code 1, offset 0
        assert!(lz4_decompress_into(&bad, &mut dest).is_err());
    }

    #[test]
    fn lz4_decode_never_panics_on_byte_flips() {
        let raw = structured(2048);
        let enc = lz4_compress(&raw);
        let mut rng = Rng::new(0xfeed);
        for _ in 0..500 {
            let mut corrupt = enc.clone();
            let pos = (rng.next() as usize) % corrupt.len();
            corrupt[pos] ^= 1 << (rng.next() % 8);
            let mut dest = vec![0u8; raw.len()];
            // either a typed error or a decode the hash layer will catch —
            // the property under test is "no panic, no overrun"
            let _ = lz4_decompress_into(&corrupt, &mut dest);
        }
    }

    #[test]
    fn qdelta_roundtrips_and_shrinks_sparse_diffs() {
        let mut rng = Rng::new(11);
        let base = rng.bytes(100_000);
        // mutate 1% of bytes arbitrarily, nudge another 5% by ±3
        let mut raw = base.clone();
        for _ in 0..1000 {
            let i = (rng.next() as usize) % raw.len();
            raw[i] = rng.next() as u8;
        }
        for _ in 0..5000 {
            let i = (rng.next() as usize) % raw.len();
            raw[i] = raw[i].wrapping_add((rng.next() % 7) as u8 + 1).wrapping_sub(3);
        }
        let enc = qdelta_encode(&raw, &base).unwrap();
        assert!(
            enc.len() * 2 < raw.len(),
            "sparse diff should encode ≤ half, got {} / {}",
            enc.len(),
            raw.len()
        );
        let mut dec = vec![0u8; raw.len()];
        qdelta_decode_into(&enc, &base, &mut dec).unwrap();
        assert_eq!(dec, raw);
    }

    #[test]
    fn qdelta_is_exact_on_dense_random_diffs() {
        // worst case: every byte differs arbitrarily — the raw escape
        // must preserve exact bytes (this is the "no quantization error"
        // guarantee)
        let mut rng = Rng::new(23);
        let base = rng.bytes(10_000);
        let raw = rng.bytes(10_000);
        let enc = qdelta_encode(&raw, &base).unwrap();
        let mut dec = vec![0u8; raw.len()];
        qdelta_decode_into(&enc, &base, &mut dec).unwrap();
        assert_eq!(dec, raw);
    }

    #[test]
    fn qdelta_edge_cases_roundtrip() {
        let cases: Vec<(Vec<u8>, Vec<u8>)> = vec![
            (Vec::new(), Vec::new()),
            (vec![1], vec![2]),
            (vec![5; 7], vec![5; 7]),                       // identical
            (vec![0; 4096], vec![255; 4096]),               // max diff everywhere
            ((0..=255).collect(), (0..=255).rev().collect()), // odd nibble counts
        ];
        for (raw, base) in cases {
            let enc = qdelta_encode(&raw, &base).unwrap();
            let mut dec = vec![0u8; raw.len()];
            qdelta_decode_into(&enc, &base, &mut dec).unwrap();
            assert_eq!(dec, raw);
        }
    }

    #[test]
    fn qdelta_fails_closed() {
        let base = structured(1024);
        let mut raw = base.clone();
        raw[100] = raw[100].wrapping_add(50);
        let enc = qdelta_encode(&raw, &base).unwrap();
        let mut dest = vec![0u8; raw.len()];
        // length mismatches
        assert!(qdelta_encode(&raw, &base[..1000]).is_err());
        assert!(qdelta_decode_into(&enc, &base[..1000], &mut dest).is_err());
        // truncations
        for cut in 0..enc.len() {
            assert!(
                qdelta_decode_into(&enc[..cut], &base, &mut dest).is_err(),
                "truncated qdelta (len {cut}) must fail"
            );
        }
        // unknown op
        let bad = vec![0x07u8, 0x01, 0x00];
        assert!(qdelta_decode_into(&bad, &base, &mut dest).is_err());
        // overrun: zero-run longer than the chunk
        let mut overrun = Vec::new();
        overrun.push(QD_ZERO);
        push_varint(&mut overrun, (base.len() + 1) as u64);
        assert!(qdelta_decode_into(&overrun, &base, &mut dest).is_err());
        // underrun: valid ops that stop short
        let mut short = Vec::new();
        short.push(QD_ZERO);
        push_varint(&mut short, (base.len() - 1) as u64);
        assert!(qdelta_decode_into(&short, &base, &mut dest).is_err());
        // varint overflow
        let huge = vec![QD_ZERO, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x7f];
        assert!(qdelta_decode_into(&huge, &base, &mut dest).is_err());
    }

    #[test]
    fn qdelta_decode_never_panics_on_byte_flips() {
        let mut rng = Rng::new(0xbeef);
        let base = rng.bytes(2048);
        let mut raw = base.clone();
        for _ in 0..64 {
            let i = (rng.next() as usize) % raw.len();
            raw[i] = raw[i].wrapping_add(3);
        }
        let enc = qdelta_encode(&raw, &base).unwrap();
        for _ in 0..500 {
            let mut corrupt = enc.clone();
            let pos = (rng.next() as usize) % corrupt.len();
            corrupt[pos] ^= 1 << (rng.next() % 8);
            let mut dest = vec![0u8; raw.len()];
            let _ = qdelta_decode_into(&corrupt, &base, &mut dest);
        }
    }

    #[test]
    fn chunk_wrappers_dispatch_and_gate_bases() {
        let mut rng = Rng::new(3);
        let base = rng.bytes(4096);
        let mut raw = base.clone();
        raw[7] ^= 0xff;
        for kind in [CodecKind::None, CodecKind::Lz4, CodecKind::QuantDelta] {
            let enc = encode_chunk(kind, &raw, Some(&base)).unwrap();
            let mut dec = vec![0u8; raw.len()];
            decode_chunk_into(kind, &enc, Some(&base), &mut dec).unwrap();
            assert_eq!(dec, raw, "{kind} wrapper roundtrip");
        }
        // qdelta without a base must fail both ways
        assert!(encode_chunk(CodecKind::QuantDelta, &raw, None).is_err());
        let enc = encode_chunk(CodecKind::QuantDelta, &raw, Some(&base)).unwrap();
        let mut dec = vec![0u8; raw.len()];
        assert!(decode_chunk_into(CodecKind::QuantDelta, &enc, None, &mut dec).is_err());
        // codec none with a length mismatch fails closed
        assert!(decode_chunk_into(CodecKind::None, &enc, None, &mut dec).is_err());
    }
}
