//! Byte-granularity write partitioning (paper §4.2, "load balancing").
//!
//! DP replicas hold identical model state, so any rank can write any
//! byte range of the serialized checkpoint. Partitioning at *byte*
//! granularity — after serialization, so it reflects exactly what goes
//! to disk — bounds load imbalance to one byte, which layer- or
//! tensor-granularity splits cannot do for heterogeneous layer sizes.
//!
//! The plan is computed once at training setup (communication-free
//! checkpointing: each writer already knows its range) and reused every
//! iteration until the topology changes. Device placement composes the
//! same way: partition `i` of a plan is striped onto device
//! `i % n_devices` of the runtime's [`crate::io::DeviceMap`] — a pure
//! function of the plan, so writers and loaders agree without
//! communication (the assignment is additionally recorded per partition
//! in the checkpoint manifest). The delta layer's segment stores reuse
//! exactly this striping with the *segment index* as the key, so
//! partitioned full checkpoints and segment-packed incremental ones
//! spread over the same devices the same way.

use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::topology::RankPlacement;
use crate::{Error, Result};

/// One writer's byte range of the serialized stream: `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    /// DP rank that writes this partition.
    pub writer_rank: usize,
    /// Position in the plan (also the device-striping key).
    pub index: usize,
    /// First byte (inclusive) of the stream range.
    pub start: u64,
    /// One past the last byte of the stream range.
    pub end: u64,
}

impl Partition {
    /// Partition length in bytes.
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    /// True for zero-length partitions.
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// A complete, validated partitioning of one checkpoint stream.
#[derive(Debug, Clone, PartialEq)]
pub struct WritePlan {
    /// Length of the serialized stream being partitioned.
    pub total_len: u64,
    /// Partitions in stream order.
    pub partitions: Vec<Partition>,
}

impl WritePlan {
    /// Split `total_len` bytes over `writers` (selected DP ranks), in
    /// rank order, near-evenly: the first `total % n` partitions get one
    /// extra byte — imbalance is at most 1 byte.
    pub fn balanced(total_len: u64, writers: &[usize]) -> Result<WritePlan> {
        if writers.is_empty() {
            return Err(Error::Config("write plan needs >= 1 writer".into()));
        }
        let n = writers.len() as u64;
        let base = total_len / n;
        let extra = total_len % n;
        let mut partitions = Vec::with_capacity(writers.len());
        let mut start = 0u64;
        for (i, &rank) in writers.iter().enumerate() {
            let len = base + u64::from((i as u64) < extra);
            partitions.push(Partition { writer_rank: rank, index: i, start, end: start + len });
            start += len;
        }
        debug_assert_eq!(start, total_len);
        Ok(WritePlan { total_len, partitions })
    }

    /// Build a plan from a DP group + writer strategy.
    pub fn from_strategy(
        total_len: u64,
        group: &[RankPlacement],
        strategy: WriterStrategy,
        sockets_per_node: usize,
    ) -> Result<WritePlan> {
        let writers = strategy.select(group, sockets_per_node)?;
        let ranks: Vec<usize> = writers.iter().map(|p| p.rank).collect();
        WritePlan::balanced(total_len, &ranks)
    }

    /// Number of writers (= partitions) in the plan.
    pub fn writers(&self) -> usize {
        self.partitions.len()
    }

    /// Max partition length (the latency-determining write).
    pub fn max_partition(&self) -> u64 {
        self.partitions.iter().map(|p| p.len()).max().unwrap_or(0)
    }

    /// Validate invariants: contiguous, disjoint, covering, balanced.
    pub fn validate(&self) -> Result<()> {
        let mut pos = 0u64;
        for (i, p) in self.partitions.iter().enumerate() {
            if p.index != i {
                return Err(Error::Internal(format!("partition {i} has index {}", p.index)));
            }
            if p.start != pos || p.end < p.start {
                return Err(Error::Internal(format!("partition {i} not contiguous")));
            }
            pos = p.end;
        }
        if pos != self.total_len {
            return Err(Error::Internal("partitions do not cover stream".into()));
        }
        let min = self.partitions.iter().map(|p| p.len()).min().unwrap_or(0);
        let max = self.max_partition();
        if max - min > 1 {
            return Err(Error::Internal(format!("imbalance {} > 1 byte", max - min)));
        }
        Ok(())
    }

    /// The partition a given writer rank owns, if any.
    pub fn for_rank(&self, rank: usize) -> Option<&Partition> {
        self.partitions.iter().find(|p| p.writer_rank == rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    #[test]
    fn splits_evenly_with_remainder_up_front() {
        let plan = WritePlan::balanced(10, &[0, 1, 2]).unwrap();
        plan.validate().unwrap();
        let lens: Vec<u64> = plan.partitions.iter().map(|p| p.len()).collect();
        assert_eq!(lens, vec![4, 3, 3]);
        assert_eq!(plan.partitions[1].start, 4);
    }

    #[test]
    fn single_writer_takes_all() {
        let plan = WritePlan::balanced(1234, &[7]).unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.partitions[0].writer_rank, 7);
        assert_eq!(plan.partitions[0].len(), 1234);
    }

    #[test]
    fn more_writers_than_bytes() {
        let plan = WritePlan::balanced(2, &[0, 1, 2, 3]).unwrap();
        plan.validate().unwrap();
        let lens: Vec<u64> = plan.partitions.iter().map(|p| p.len()).collect();
        assert_eq!(lens, vec![1, 1, 0, 0]);
    }

    #[test]
    fn zero_length_stream() {
        let plan = WritePlan::balanced(0, &[0, 1]).unwrap();
        plan.validate().unwrap();
        assert!(plan.partitions.iter().all(|p| p.is_empty()));
    }

    #[test]
    fn no_writers_is_error() {
        assert!(WritePlan::balanced(10, &[]).is_err());
    }

    #[test]
    fn for_rank_lookup() {
        let plan = WritePlan::balanced(100, &[4, 9]).unwrap();
        assert_eq!(plan.for_rank(9).unwrap().index, 1);
        assert!(plan.for_rank(5).is_none());
    }

    #[test]
    fn prop_partition_invariants() {
        forall("balanced plan invariants", 256, |g| {
            let total = g.u64(0, 1 << 42);
            let n = g.usize(1, 64);
            let writers: Vec<usize> = (0..n).collect();
            let plan = WritePlan::balanced(total, &writers).unwrap();
            plan.validate().is_ok()
                && plan.partitions.len() == n
                && plan.partitions.iter().map(|p| p.len()).sum::<u64>() == total
        });
    }

    #[test]
    fn prop_deterministic() {
        forall("plans are deterministic", 64, |g| {
            let total = g.u64(0, 1 << 30);
            let n = g.usize(1, 16);
            let writers: Vec<usize> = (0..n).map(|i| i * 3).collect();
            WritePlan::balanced(total, &writers).unwrap()
                == WritePlan::balanced(total, &writers).unwrap()
        });
    }
}
