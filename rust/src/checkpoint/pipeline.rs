//! Pipelined (decoupled) checkpointing (paper §4.3).
//!
//! Fig. 3's dependency analysis: checkpoint *C_i* depends on optimizer
//! *O_i* (it reads the updated model) and *O_{i+1}* depends on *C_i*
//! completing (otherwise a failure could lose an un-persisted update
//! while training has already moved past it). Forward/backward of
//! iteration *i+1* depend on neither, so *C_i* can overlap them.
//!
//! Protocol (per §4.3's main/helper cooperation):
//!
//! ```text
//! main thread                          helper thread
//! ───────────                          ─────────────
//! F_i, B_i
//! wait_previous()  ◄─────────────────  done(C_{i-1})
//! O_i
//! request(snapshot_i)  ──────────────► write C_i (direct to durable
//! F_{i+1}, B_{i+1}   (overlapped)        storage — no volatile
//! wait_previous()  ◄─────────────────    snapshot phase)
//! O_{i+1} ...
//! ```
//!
//! The snapshot is an `Arc` clone of the tensor buffers (zero copy); the
//! helper never allocates payload memory and never blocks the main
//! thread except at the `wait_previous` synchronization point — which is
//! exactly the paper's stall-only-if-checkpoint-still-running semantics.
//!
//! The helper owns no I/O resources: it submits partitions into the
//! engine's shared [`crate::io::IoRuntime`] (staging pool + persistent
//! writer threads + per-device drain lanes), so pipelined and direct
//! checkpoints interleave through one submission queue, and
//! back-to-back checkpoints reuse the same staging buffers. Each
//! submission is **planned** on the helper thread (the job's
//! [`crate::io::WritePlan`] op schedule) and executed by the shared
//! [`crate::io::WritePipeline`] — the helper inherits the same
//! probe-gated O_DIRECT/bounce accounting as synchronous writes, so
//! pipelined outcomes report `direct_bytes`/`bounce_bytes` too.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::checkpoint::delta::DeltaCheckpointer;
use crate::checkpoint::engine::{CheckpointEngine, CheckpointOutcome};
use crate::cluster::topology::RankPlacement;
use crate::tensor::TensorStore;
use crate::util::json::Json;
use crate::{Error, Result};

struct Request {
    snapshot: TensorStore,
    extra: BTreeMap<String, Json>,
    dir: PathBuf,
}

/// What a checkpoint worker thread runs per request: a full parallel
/// write or an incremental delta write (segment-packed — the worker
/// inherits the same bounded WriteJob/fsync profile as synchronous delta
/// writes). Owned by the worker thread so stateful writers (the delta
/// chain diff state) live where the writes happen. Shared between the
/// eager pipelined helper here and the lazy flush scheduler
/// ([`crate::checkpoint::lazy`]).
pub(crate) enum HelperWriter {
    /// Full-snapshot parallel write over a fixed DP writer group.
    Full {
        /// The shared-runtime checkpoint engine.
        engine: CheckpointEngine,
        /// The DP group used for every checkpoint (fixed at setup, §4.2).
        group: Vec<RankPlacement>,
    },
    /// Incremental delta write (chain state lives on the worker thread).
    Delta(DeltaCheckpointer),
}

impl HelperWriter {
    pub(crate) fn write(
        &mut self,
        snapshot: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
    ) -> Result<CheckpointOutcome> {
        match self {
            HelperWriter::Full { engine, group } => engine.write(snapshot, extra, dir, group),
            HelperWriter::Delta(ckpt) => ckpt
                .write(snapshot, extra, dir)
                .map(crate::checkpoint::delta::DeltaOutcome::into_outcome),
        }
    }
}

/// Decoupled checkpoint executor: owns a helper thread running the
/// checkpoint engine.
pub struct PipelinedCheckpointer {
    req_tx: Option<Sender<Request>>,
    done_rx: Receiver<Result<CheckpointOutcome>>,
    helper: Option<JoinHandle<()>>,
    outstanding: bool,
    /// Cumulative time the main thread spent blocked in wait_previous —
    /// the checkpoint *stall* the paper measures as training overhead.
    pub stall: Duration,
    /// Outcomes of every completed checkpoint, in order.
    pub completed: Vec<CheckpointOutcome>,
}

impl PipelinedCheckpointer {
    /// Spawn the helper around `engine`; `group` is the DP group used
    /// for every checkpoint (fixed at setup, §4.2).
    pub fn new(engine: CheckpointEngine, group: Vec<RankPlacement>) -> PipelinedCheckpointer {
        Self::with_writer(HelperWriter::Full { engine, group })
    }

    /// Spawn the helper around an incremental [`DeltaCheckpointer`]:
    /// per-iteration delta checkpoints overlapped with forward/backward,
    /// with the chain diff state living on the helper thread.
    pub fn delta(ckpt: DeltaCheckpointer) -> PipelinedCheckpointer {
        Self::with_writer(HelperWriter::Delta(ckpt))
    }

    fn with_writer(mut writer: HelperWriter) -> PipelinedCheckpointer {
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (done_tx, done_rx) = mpsc::channel();
        let helper = std::thread::Builder::new()
            .name("ckpt-helper".into())
            .spawn(move || {
                // Infinite loop: block for a request, write, signal (§4.3).
                for req in req_rx {
                    let Request { snapshot, extra, dir } = req;
                    let result = writer.write(&snapshot, extra, &dir);
                    if done_tx.send(result).is_err() {
                        break; // main side gone
                    }
                }
            })
            .expect("spawn checkpoint helper");
        PipelinedCheckpointer {
            req_tx: Some(req_tx),
            done_rx,
            helper: Some(helper),
            outstanding: false,
            stall: Duration::ZERO,
            completed: Vec::new(),
        }
    }

    /// Block until the previously requested checkpoint (if any) is
    /// durable. Call **before** the optimizer step.
    pub fn wait_previous(&mut self) -> Result<()> {
        if !self.outstanding {
            return Ok(());
        }
        let t0 = Instant::now();
        let outcome = self
            .done_rx
            .recv()
            .map_err(|_| Error::Internal("checkpoint helper died".into()))??;
        self.stall += t0.elapsed();
        self.outstanding = false;
        self.completed.push(outcome);
        Ok(())
    }

    /// Hand the post-optimizer state to the helper. Call **after** the
    /// optimizer step. The snapshot is zero-copy (`Arc` clones).
    pub fn request(
        &mut self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: PathBuf,
    ) -> Result<()> {
        assert!(
            !self.outstanding,
            "request() while a checkpoint is outstanding — call wait_previous() first"
        );
        self.req_tx
            .as_ref()
            .expect("checkpointer finished")
            .send(Request { snapshot: store.snapshot(), extra, dir })
            .map_err(|_| Error::Internal("checkpoint helper died".into()))?;
        self.outstanding = true;
        Ok(())
    }

    /// True if a checkpoint write is currently in flight.
    pub fn in_flight(&self) -> bool {
        self.outstanding
    }

    /// Drain the last outstanding checkpoint and shut the helper down;
    /// returns all completed outcomes.
    pub fn finish(mut self) -> Result<Vec<CheckpointOutcome>> {
        self.wait_previous()?;
        drop(self.req_tx.take());
        if let Some(h) = self.helper.take() {
            h.join().map_err(|_| Error::Internal("helper panicked".into()))?;
        }
        Ok(std::mem::take(&mut self.completed))
    }
}

impl Drop for PipelinedCheckpointer {
    fn drop(&mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.helper.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::load::load_checkpoint;
    use crate::checkpoint::strategy::WriterStrategy;
    use crate::io::engine::scratch_dir;
    use crate::tensor::{DType, Tensor};
    use crate::util::rng::Rng;

    fn solo_group() -> Vec<RankPlacement> {
        vec![RankPlacement { rank: 0, node: 0, socket: 0, local_gpu: 0 }]
    }

    fn store_with(step: u8, nbytes: usize) -> TensorStore {
        let mut s = TensorStore::new();
        let mut data = vec![step; nbytes];
        Rng::new(step as u64).fill_bytes(&mut data[..nbytes / 2]);
        s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
        s
    }

    fn extra(step: i64) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("step".into(), Json::Int(step));
        m
    }

    #[test]
    fn overlapped_iterations_produce_every_checkpoint() {
        let dir = scratch_dir("pipe-every").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let rt = std::sync::Arc::clone(engine.runtime());
        let mut pipe = PipelinedCheckpointer::new(engine, solo_group());
        let iters = 5;
        for i in 0..iters {
            // F/B of iteration i would run here, overlapped with C_{i-1}
            pipe.wait_previous().unwrap(); // before O_i
            let store = store_with(i as u8, 200_000); // O_i output
            pipe.request(&store, extra(i), dir.join(format!("step{i}"))).unwrap();
        }
        let outcomes = pipe.finish().unwrap();
        assert_eq!(outcomes.len(), iters as usize);
        // every checkpoint corresponds to exactly its iteration's state
        for i in 0..iters {
            let (loaded, header, _) = load_checkpoint(&dir.join(format!("step{i}")), &rt).unwrap();
            assert_eq!(header.extra["step"], Json::Int(i));
            assert!(loaded.content_eq(&store_with(i as u8, 200_000)));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn snapshot_isolates_from_next_optimizer_update() {
        // The checkpoint of iteration i must contain O_i's output even if
        // the main thread mutates the store while the write is in flight.
        let dir = scratch_dir("pipe-iso").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let rt = std::sync::Arc::clone(engine.runtime());
        let mut pipe = PipelinedCheckpointer::new(engine, solo_group());
        let mut store = store_with(1, 500_000);
        pipe.request(&store, extra(1), dir.join("c1")).unwrap();
        // "next iteration" mutates the live store immediately
        store.update("w", vec![99u8; 500_000]).unwrap();
        pipe.wait_previous().unwrap();
        let (loaded, _, _) = load_checkpoint(&dir.join("c1"), &rt).unwrap();
        assert!(loaded.content_eq(&store_with(1, 500_000)));
        drop(pipe);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[should_panic(expected = "outstanding")]
    fn double_request_without_wait_panics() {
        let dir = scratch_dir("pipe-double").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let mut pipe = PipelinedCheckpointer::new(engine, solo_group());
        let store = store_with(0, 1000);
        pipe.request(&store, extra(0), dir.join("a")).unwrap();
        // violates the O_{i+1} -> C_i dependency: must wait first
        let _ = pipe.request(&store, extra(1), dir.join("b"));
    }

    #[test]
    fn stall_accounts_wait_time() {
        let dir = scratch_dir("pipe-stall").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let mut pipe = PipelinedCheckpointer::new(engine, solo_group());
        let store = store_with(0, 4 << 20);
        pipe.request(&store, extra(0), dir.join("c")).unwrap();
        // no overlapped compute: all write time becomes stall
        pipe.wait_previous().unwrap();
        assert!(pipe.stall > Duration::ZERO);
        drop(pipe);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn finish_without_requests_is_ok() {
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let pipe = PipelinedCheckpointer::new(engine, solo_group());
        assert!(pipe.finish().unwrap().is_empty());
    }

    #[test]
    fn pipelined_delta_chain_reloads_every_step() {
        use crate::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
        use crate::io::engine::IoConfig;
        use crate::io::runtime::{IoRuntime, IoRuntimeConfig};
        use std::sync::Arc;

        let dir = scratch_dir("pipe-delta").unwrap();
        let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            ..IoRuntimeConfig::default()
        }));
        let ckpt = DeltaCheckpointer::new(
            Arc::clone(&rt),
            DeltaConfig { chunk_size: 4096, max_chain: 8, ..DeltaConfig::default() },
        );
        let mut pipe = PipelinedCheckpointer::delta(ckpt);
        for i in 0..4i64 {
            pipe.wait_previous().unwrap();
            let store = store_with(i as u8, 120_000);
            pipe.request(&store, extra(i), dir.join(format!("step-{i:08}"))).unwrap();
        }
        let outcomes = pipe.finish().unwrap();
        assert_eq!(outcomes.len(), 4);
        // later checkpoints are deltas off the first (base) one
        assert!(outcomes[1].manifest.is_delta());
        assert_eq!(outcomes[1].manifest.delta.as_ref().unwrap().chain_len, 1);
        for i in 0..4i64 {
            let (loaded, header, _) =
                load_checkpoint(&dir.join(format!("step-{i:08}")), &rt).unwrap();
            assert_eq!(header.extra["step"], Json::Int(i));
            assert!(loaded.content_eq(&store_with(i as u8, 120_000)));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
