//! On-disk checkpoint container format.
//!
//! ```text
//! offset 0:  magic  "FPCK"                      (4 bytes)
//!            version u32 LE                     (4 bytes)
//!            header_len u64 LE                  (8 bytes)
//!            header JSON (header_len bytes)
//!            data section (tensor payloads, contiguous, in header order)
//! ```
//!
//! The header JSON carries the tensor metadata table (name/dtype/shape/
//! offset — the serialized-tensor metadata of §2.1.3), free-form `extra`
//! training state (step counter, data-iterator cursor, LR schedule), the
//! data-section length, and a 64-bit digest of the data section for
//! integrity verification at load.
//!
//! # Examples
//!
//! [`ChunkedChecksum`] digests a byte section **and** its fixed-size
//! chunk grid in one pass — the primitive that lets
//! [`crate::checkpoint::delta`] fold dirty-chunk hashing into the
//! serialization pass instead of re-reading the whole state:
//!
//! ```
//! use fastpersist::serialize::format::{checksum64_slice, ChunkedChecksum};
//!
//! let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
//! let mut cc = ChunkedChecksum::new(4096);
//! cc.update(&data[..1000]); // any chunking of the input
//! cc.update(&data[1000..]);
//! let (whole, grid) = cc.finalize();
//!
//! // the section digest equals the plain one-shot checksum ...
//! assert_eq!(whole, checksum64_slice(&data));
//! // ... and each grid entry equals the checksum of its slice
//! assert_eq!(grid.len(), 3);
//! assert_eq!(grid[0].hash, checksum64_slice(&data[..4096]));
//! assert_eq!(grid[2].len, 10_000 - 2 * 4096);
//! ```

use std::collections::BTreeMap;

use crate::tensor::TensorMeta;
use crate::util::json::Json;
use crate::{Error, Result};

/// Container magic bytes.
pub const MAGIC: [u8; 4] = *b"FPCK";
/// Container format version.
pub const VERSION: u32 = 1;
/// Fixed-size preamble before the header JSON.
pub const PREAMBLE_LEN: usize = 16;
/// Encoded headers (preamble + JSON) are space-padded up to a multiple
/// of this. Integer fields in the header JSON (digests, step counters)
/// jitter in decimal width between checkpoints; without padding that
/// jitter shifts every payload byte, which would turn almost every
/// chunk dirty under [`crate::checkpoint::delta`]'s fixed chunk grid.
/// Trailing spaces are JSON whitespace, so decoding is unchanged.
pub const HEADER_PAD: usize = 256;

/// Parsed header of a checkpoint stream.
#[derive(Debug, Clone, PartialEq)]
pub struct FormatHeader {
    /// Tensor metadata table, in payload order.
    pub tensors: Vec<TensorMeta>,
    /// Free-form training extras (step, lr, data cursor, ...).
    pub extra: BTreeMap<String, Json>,
    /// Data-section length in bytes.
    pub data_len: u64,
    /// Digest of the data section.
    pub digest: u64,
}

impl FormatHeader {
    /// Serialize to the header JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::from(VERSION as i64)),
            ("tensors", Json::arr(self.tensors.iter().map(|t| t.to_json()))),
            ("extra", Json::Object(self.extra.clone())),
            ("data_len", Json::from(self.data_len as i64)),
            // u64 digest split to stay inside i64-safe JSON integers
            ("digest_hi", Json::from((self.digest >> 32) as i64)),
            ("digest_lo", Json::from((self.digest & 0xffff_ffff) as i64)),
        ])
    }

    /// Parse from the header JSON document.
    pub fn from_json(v: &Json) -> Result<FormatHeader> {
        let version = v.get("version")?.as_i64()?;
        if version != VERSION as i64 {
            return Err(Error::Format(format!("unsupported version {version}")));
        }
        let tensors = v
            .get("tensors")?
            .as_array()?
            .iter()
            .map(TensorMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let extra = v.get("extra")?.as_object()?.clone();
        let hi = v.get("digest_hi")?.as_i64()? as u64;
        let lo = v.get("digest_lo")?.as_i64()? as u64;
        Ok(FormatHeader {
            tensors,
            extra,
            data_len: v.get("data_len")?.as_i64()? as u64,
            digest: (hi << 32) | (lo & 0xffff_ffff),
        })
    }

    /// Encode preamble + header JSON into bytes, space-padded so the
    /// total is a multiple of [`HEADER_PAD`] (stable payload offsets
    /// across checkpoints of the same model — see [`HEADER_PAD`]).
    pub fn encode(&self) -> Vec<u8> {
        let json = self.to_json().to_string_compact();
        let total = (PREAMBLE_LEN + json.len()).next_multiple_of(HEADER_PAD);
        let hlen = total - PREAMBLE_LEN;
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(hlen as u64).to_le_bytes());
        out.extend_from_slice(json.as_bytes());
        out.resize(total, b' ');
        out
    }

    /// Decode from the start of `bytes`; returns (header, header_bytes).
    pub fn decode(bytes: &[u8]) -> Result<(FormatHeader, usize)> {
        let end = header_extent(bytes)?;
        let json = std::str::from_utf8(&bytes[PREAMBLE_LEN..end])
            .map_err(|_| Error::Format("header not utf-8".into()))?;
        let header = FormatHeader::from_json(&Json::parse(json)?)?;
        Ok((header, end))
    }
}

/// Streaming 64-bit checksum (not crypto; an integrity check against
/// torn/partial parallel writes). Chunking-invariant: feeding the same
/// bytes in any split produces the same digest. The aligned interior of
/// each chunk is processed 8 bytes per step (memory-bound in release).
#[derive(Debug, Clone)]
pub struct Checksum64 {
    h: u64,
    carry: u64,
    carry_len: usize,
}

impl Default for Checksum64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Checksum64 {
    /// A fresh digest state.
    pub fn new() -> Checksum64 {
        Checksum64 { h: 0xcbf29ce484222325, carry: 0, carry_len: 0 }
    }

    #[inline]
    fn mix(&mut self, word: u64) {
        const MUL: u64 = 0x9e3779b97f4a7c15;
        self.h = (self.h ^ word).wrapping_mul(MUL);
        self.h ^= self.h >> 29;
    }

    /// Feed bytes into the digest (any chunking).
    pub fn update(&mut self, mut data: &[u8]) {
        // finish a pending partial word byte-by-byte
        while self.carry_len > 0 && !data.is_empty() {
            self.carry |= (data[0] as u64) << (8 * self.carry_len);
            self.carry_len += 1;
            data = &data[1..];
            if self.carry_len == 8 {
                let word = self.carry;
                self.carry = 0;
                self.carry_len = 0;
                self.mix(word);
            }
        }
        if data.is_empty() {
            return; // a partial word may still be pending in carry
        }
        // here carry is empty: fast path over whole words
        debug_assert_eq!(self.carry_len, 0);
        let mut words = data.chunks_exact(8);
        for w in &mut words {
            self.mix(u64::from_le_bytes(w.try_into().unwrap()));
        }
        // stash the tail
        for (i, &b) in words.remainder().iter().enumerate() {
            self.carry |= (b as u64) << (8 * i);
        }
        self.carry_len = words.remainder().len();
    }

    /// Consume the state and produce the digest value.
    pub fn finalize(mut self) -> u64 {
        if self.carry_len > 0 {
            let word = self.carry | ((self.carry_len as u64) << 56);
            self.mix(word);
        }
        self.h
    }
}

/// Hash + length of one chunk of a digested byte section — the unit of
/// dirty-chunk diffing in [`crate::checkpoint::delta`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkDigest {
    /// Streaming checksum of the chunk's bytes (equals
    /// [`checksum64_slice`] over the same slice).
    pub hash: u64,
    /// Chunk length in bytes (== grid size except for the final chunk).
    pub len: u64,
}

/// Single-pass section digest **plus** fixed-grid chunk digests.
///
/// Feeding the same bytes in any split produces the same results
/// (chunking-invariant, like [`Checksum64`]). The section digest equals
/// [`checksum64`] over the full input; chunk `i`'s hash equals
/// [`checksum64_slice`] of input bytes `[i*chunk_size, ...)`. This is
/// how serialization hands the delta layer its chunk grid without a
/// second pass over the state bytes (see the module example).
#[derive(Debug, Clone)]
pub struct ChunkedChecksum {
    chunk_size: u64,
    whole: Checksum64,
    cur: Checksum64,
    filled: u64,
    chunks: Vec<ChunkDigest>,
}

impl ChunkedChecksum {
    /// A fresh digest over a `chunk_size`-byte grid (must be nonzero).
    pub fn new(chunk_size: u64) -> ChunkedChecksum {
        assert!(chunk_size > 0, "chunk_size must be positive");
        ChunkedChecksum {
            chunk_size,
            whole: Checksum64::new(),
            cur: Checksum64::new(),
            filled: 0,
            chunks: Vec::new(),
        }
    }

    /// Feed bytes (any chunking); grid boundaries are tracked internally.
    pub fn update(&mut self, data: &[u8]) {
        self.whole.update(data);
        let mut rest = data;
        while !rest.is_empty() {
            let room = (self.chunk_size - self.filled).min(rest.len() as u64) as usize;
            self.cur.update(&rest[..room]);
            self.filled += room as u64;
            rest = &rest[room..];
            if self.filled == self.chunk_size {
                let done = std::mem::replace(&mut self.cur, Checksum64::new());
                self.chunks.push(ChunkDigest { hash: done.finalize(), len: self.chunk_size });
                self.filled = 0;
            }
        }
    }

    /// Consume the state: `(section digest, chunk grid)`. A trailing
    /// partial chunk becomes the final (short) grid entry; empty input
    /// yields an empty grid.
    pub fn finalize(mut self) -> (u64, Vec<ChunkDigest>) {
        if self.filled > 0 {
            self.chunks.push(ChunkDigest { hash: self.cur.finalize(), len: self.filled });
        }
        (self.whole.finalize(), self.chunks)
    }
}

/// Combine the header digest and the data digest into the checkpoint's
/// *stream digest* (order-sensitive: swapping the halves changes it).
///
/// Writers compute the data digest during the **single** payload
/// traversal of serialization, hash the (KB-scale) header bytes, and
/// combine — the manifest digest no longer costs a second full-stream
/// pass per checkpoint. Loaders recompute both halves from the
/// assembled stream (see [`stream_digest_of`]) and compare.
pub fn combine_digests(header_digest: u64, data_digest: u64) -> u64 {
    const MUL: u64 = 0x9e3779b97f4a7c15;
    let mut h: u64 = 0x84222325_cbf29ce4; // distinct IV from Checksum64
    h = (h ^ header_digest).wrapping_mul(MUL);
    h ^= h >> 29;
    h = (h ^ data_digest).wrapping_mul(MUL);
    h ^= h >> 29;
    h
}

/// Byte length of the container prefix (preamble + header JSON) at the
/// start of `bytes`; validates magic/version/bounds without parsing the
/// header JSON itself.
pub fn header_extent(bytes: &[u8]) -> Result<usize> {
    if bytes.len() < PREAMBLE_LEN {
        return Err(Error::Format("truncated preamble".into()));
    }
    if bytes[..4] != MAGIC {
        return Err(Error::Format(format!("bad magic {:?}", &bytes[..4])));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(Error::Format(format!("unsupported version {version}")));
    }
    let hlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let end = PREAMBLE_LEN
        .checked_add(hlen)
        .ok_or_else(|| Error::Format("header length overflow".into()))?;
    if bytes.len() < end {
        return Err(Error::Format("truncated header".into()));
    }
    Ok(end)
}

/// Stream digest of a fully assembled checkpoint stream: header digest
/// and data digest computed in one linear scan, then combined. This is
/// the loader-side counterpart of the writer's single-pass digest.
pub fn stream_digest_of(stream: &[u8]) -> Result<u64> {
    let end = header_extent(stream)?;
    Ok(combine_digests(checksum64_slice(&stream[..end]), checksum64_slice(&stream[end..])))
}

/// Checksum over an iterator of chunks (chunking-invariant).
pub fn checksum64(chunks: impl Iterator<Item = impl AsRef<[u8]>>) -> u64 {
    let mut c = Checksum64::new();
    for chunk in chunks {
        c.update(chunk.as_ref());
    }
    c.finalize()
}

/// Checksum over a single contiguous slice (8-bytes-at-a-time fast path).
pub fn checksum64_slice(data: &[u8]) -> u64 {
    const MUL: u64 = 0x9e3779b97f4a7c15;
    let mut h: u64 = 0xcbf29ce484222325;
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let word = u64::from_le_bytes(c.try_into().unwrap());
        h = (h ^ word).wrapping_mul(MUL);
        h ^= h >> 29;
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut carry = 0u64;
        for (i, &b) in rem.iter().enumerate() {
            carry |= (b as u64) << (8 * i);
        }
        carry |= (rem.len() as u64) << 56;
        h = (h ^ carry).wrapping_mul(MUL);
        h ^= h >> 29;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    fn header() -> FormatHeader {
        let mut extra = BTreeMap::new();
        extra.insert("step".to_string(), Json::Int(42));
        FormatHeader {
            tensors: vec![
                TensorMeta { name: "a".into(), dtype: DType::F32, shape: vec![4], offset: 0 },
                TensorMeta { name: "b".into(), dtype: DType::F16, shape: vec![2, 2], offset: 16 },
            ],
            extra,
            data_len: 24,
            digest: 0xdead_beef_cafe_f00d,
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let h = header();
        let enc = h.encode();
        let (dec, consumed) = FormatHeader::decode(&enc).unwrap();
        assert_eq!(dec, h);
        assert_eq!(consumed, enc.len());
    }

    #[test]
    fn header_length_is_padded_and_stable_across_integer_jitter() {
        // Different digests/steps have different decimal widths; the
        // padded encoding must keep the header length identical so
        // payload offsets don't shift between checkpoints (the delta
        // layer's chunk grid depends on this).
        let mut a = header();
        let mut b = header();
        a.digest = 1; // "1" — shortest possible digit strings
        b.digest = u64::MAX; // longest
        b.extra.insert("step".to_string(), Json::Int(999_999));
        let ea = a.encode();
        let eb = b.encode();
        assert_eq!(ea.len() % HEADER_PAD, 0);
        assert_eq!(ea.len(), eb.len(), "digit jitter must not change header length");
        // padding decodes transparently
        let (da, consumed) = FormatHeader::decode(&ea).unwrap();
        assert_eq!(da, a);
        assert_eq!(consumed, ea.len());
    }

    #[test]
    fn decode_with_trailing_data_ok() {
        let mut enc = header().encode();
        let hdr_len = enc.len();
        enc.extend_from_slice(&[0u8; 24]);
        let (_, consumed) = FormatHeader::decode(&enc).unwrap();
        assert_eq!(consumed, hdr_len);
    }

    #[test]
    fn rejects_corruption() {
        let h = header();
        let enc = h.encode();
        // bad magic
        let mut bad = enc.clone();
        bad[0] = b'X';
        assert!(FormatHeader::decode(&bad).is_err());
        // bad version
        let mut bad = enc.clone();
        bad[4] = 99;
        assert!(FormatHeader::decode(&bad).is_err());
        // truncation at every prefix must error, never panic
        for cut in [0, 3, 15, 17, enc.len() - 1] {
            assert!(FormatHeader::decode(&enc[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn stream_digest_splits_at_header_boundary() {
        let h = header();
        let mut stream = h.encode();
        let hdr_len = stream.len();
        stream.extend_from_slice(&[7u8; 24]);
        let expect = combine_digests(
            checksum64_slice(&stream[..hdr_len]),
            checksum64_slice(&stream[hdr_len..]),
        );
        assert_eq!(stream_digest_of(&stream).unwrap(), expect);
        // sensitive to either half
        let mut bad_data = stream.clone();
        *bad_data.last_mut().unwrap() ^= 1;
        assert_ne!(stream_digest_of(&bad_data).unwrap(), expect);
        let mut bad_hdr = stream.clone();
        bad_hdr[PREAMBLE_LEN + 1] ^= 1;
        assert_ne!(stream_digest_of(&bad_hdr).unwrap(), expect);
        // truncated stream is an error, not a wrong digest
        assert!(stream_digest_of(&stream[..10]).is_err());
    }

    #[test]
    fn combine_digests_is_order_sensitive() {
        assert_ne!(combine_digests(1, 2), combine_digests(2, 1));
        assert_ne!(combine_digests(0, 0), 0);
    }

    #[test]
    fn checksum_chunking_invariant() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_001).collect();
        let whole = checksum64_slice(&data);
        let c1 = checksum64(data.chunks(7));
        let c2 = checksum64(data.chunks(4096));
        let c3 = checksum64([&data[..1], &data[1..]].into_iter());
        assert_eq!(whole, c1);
        assert_eq!(whole, c2);
        assert_eq!(whole, c3);
    }

    #[test]
    fn checksum_detects_changes() {
        let a = vec![1u8; 1000];
        let mut b = a.clone();
        b[999] = 2;
        assert_ne!(checksum64_slice(&a), checksum64_slice(&b));
        // length extension with zeros changes it too
        let mut c = a.clone();
        c.push(0);
        assert_ne!(checksum64_slice(&a), checksum64_slice(&c));
    }

    #[test]
    fn chunked_checksum_matches_slice_checksums() {
        let data: Vec<u8> = (0..=255u8).cycle().take(3 * 4096 + 123).collect();
        let mut cc = ChunkedChecksum::new(4096);
        // feed in awkward pieces spanning grid boundaries
        cc.update(&data[..5000]);
        cc.update(&data[5000..5001]);
        cc.update(&data[5001..]);
        let (whole, grid) = cc.finalize();
        assert_eq!(whole, checksum64_slice(&data));
        assert_eq!(grid.len(), 4);
        let mut off = 0usize;
        for (i, ch) in grid.iter().enumerate() {
            let end = off + ch.len as usize;
            assert_eq!(ch.hash, checksum64_slice(&data[off..end]), "chunk {i}");
            off = end;
        }
        assert_eq!(off, data.len());
        // exact-multiple input has no short tail chunk
        let mut cc = ChunkedChecksum::new(64);
        cc.update(&data[..128]);
        let (_, grid) = cc.finalize();
        assert_eq!(grid.len(), 2);
        assert!(grid.iter().all(|c| c.len == 64));
        // empty input: empty grid, digest of nothing
        let (whole, grid) = ChunkedChecksum::new(64).finalize();
        assert_eq!(whole, checksum64_slice(&[]));
        assert!(grid.is_empty());
    }

    #[test]
    fn prop_chunked_checksum_split_invariance() {
        crate::prop::forall("chunked checksum split-invariant", 32, |g| {
            let len = g.usize(0, 3000);
            let mut data = vec![0u8; len];
            crate::util::rng::Rng::new(g.u64(0, u64::MAX)).fill_bytes(&mut data);
            let cs = g.usize(1, 600) as u64;
            let split = g.usize(0, len);
            let mut a = ChunkedChecksum::new(cs);
            a.update(&data);
            let mut b = ChunkedChecksum::new(cs);
            b.update(&data[..split]);
            b.update(&data[split..]);
            a.finalize() == b.finalize()
        });
    }

    #[test]
    fn prop_checksum_split_invariance() {
        crate::prop::forall("checksum split-invariant", 64, |g| {
            let len = g.usize(0, 4000);
            let mut data = vec![0u8; len];
            crate::util::rng::Rng::new(g.u64(0, u64::MAX)).fill_bytes(&mut data);
            let split = g.usize(0, len);
            let whole = checksum64_slice(&data);
            let parts = checksum64([&data[..split], &data[split..]].into_iter());
            whole == parts
        });
    }
}
