//! Checkpoint serialization — the `torch.save()`-compatible layer.
//!
//! A checkpoint is a single logical byte stream: a self-describing
//! header (tensor metadata table + training extras, §2.1.3) followed by
//! the tensor payloads in declaration order, closed by a digest. The
//! stream abstraction matters: FastPersist's DP write parallelism
//! partitions the *serialized stream* at byte granularity (§4.2), so
//! [`writer::SerializedCheckpoint::write_range_to`] can emit any byte
//! subrange without materializing the whole stream — and
//! [`writer::SerializedCheckpoint::new_chunked`] folds the delta
//! layer's chunk-grid hashing into the same single serialization pass.

pub mod format;
pub mod reader;
pub mod writer;

pub use format::{checksum64, checksum64_slice, FormatHeader, MAGIC, VERSION};
pub use reader::read_checkpoint;
pub use writer::SerializedCheckpoint;
