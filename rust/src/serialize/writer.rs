//! Serialized-checkpoint view: header + zero-copy payload references.
//!
//! `SerializedCheckpoint` is the bridge between a [`TensorStore`]
//! snapshot and the write engines. It materializes only the header; the
//! tensor payloads stay as `Arc` references into the snapshot (the
//! helper thread "does not allocate GPU memory … reads existing
//! tensors", §4.3). Any byte range of the logical stream can be emitted
//! — the primitive the byte-granularity DP partitioner builds on.

use std::collections::BTreeMap;

use crate::io::pending_queue::PendingQueue;
use crate::io::Sink;
use crate::serialize::format::{
    checksum64, checksum64_slice, combine_digests, ChunkDigest, ChunkedChecksum, FormatHeader,
};
use crate::tensor::TensorStore;
use crate::util::json::Json;
use crate::Result;

/// Coalesce threshold for serializer→sink writes (PendingQueue flush).
const COALESCE: usize = 1 << 20;

/// An immutable serialized view of one checkpoint.
pub struct SerializedCheckpoint {
    header_bytes: Vec<u8>,
    snapshot: TensorStore,
    data_len: u64,
    /// Digest of the whole logical stream (header ‖ data), folded from
    /// the single serialization-time payload pass — the checkpoint
    /// engine records this in the manifest without re-hashing.
    stream_digest: u64,
    /// `(chunk_size, grid)` when built via
    /// [`SerializedCheckpoint::new_chunked`]: chunk 0 is the whole
    /// header, chunks 1.. tile the data section on the fixed grid.
    chunk_grid: Option<(u64, Vec<ChunkDigest>)>,
}

impl SerializedCheckpoint {
    /// Serialize `store` (cheap: snapshots Arcs, encodes header JSON,
    /// **one** digest pass over payload bytes — the data digest feeds
    /// both the header and, combined with the header digest, the
    /// manifest's stream digest; the engine's former second full-stream
    /// hash per checkpoint is gone).
    pub fn new(store: &TensorStore, extra: BTreeMap<String, Json>) -> SerializedCheckpoint {
        let snapshot = store.snapshot();
        let data_len = snapshot.total_bytes();
        let data_digest = checksum64(snapshot.iter().map(|t| t.data.as_slice()));
        let header =
            FormatHeader { tensors: snapshot.metas(), extra, data_len, digest: data_digest };
        let header_bytes = header.encode();
        let stream_digest = combine_digests(checksum64_slice(&header_bytes), data_digest);
        SerializedCheckpoint { header_bytes, snapshot, data_len, stream_digest, chunk_grid: None }
    }

    /// Like [`SerializedCheckpoint::new`], additionally computing the
    /// delta layer's chunk grid **inside** the same single payload pass
    /// (a [`ChunkedChecksum`] feeds both the data digest and the
    /// per-chunk hashes, so delta creation makes exactly one CPU pass
    /// over the state bytes).
    ///
    /// The grid is header-split: chunk 0 covers the encoded header
    /// (whatever its padded length), chunks 1.. tile the data section in
    /// `chunk_size` steps. Keeping the grid *data-relative* means a
    /// header that grows past a padding boundary shifts no data chunk —
    /// only chunk 0 changes.
    pub fn new_chunked(
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        chunk_size: u64,
    ) -> SerializedCheckpoint {
        assert!(chunk_size > 0, "chunk_size must be positive");
        let snapshot = store.snapshot();
        let data_len = snapshot.total_bytes();
        let mut cc = ChunkedChecksum::new(chunk_size);
        for t in snapshot.iter() {
            cc.update(t.data.as_slice());
        }
        let (data_digest, data_grid) = cc.finalize();
        let header =
            FormatHeader { tensors: snapshot.metas(), extra, data_len, digest: data_digest };
        let header_bytes = header.encode();
        let header_digest = checksum64_slice(&header_bytes);
        let stream_digest = combine_digests(header_digest, data_digest);
        let mut grid = Vec::with_capacity(data_grid.len() + 1);
        grid.push(ChunkDigest { hash: header_digest, len: header_bytes.len() as u64 });
        grid.extend(data_grid);
        SerializedCheckpoint {
            header_bytes,
            snapshot,
            data_len,
            stream_digest,
            chunk_grid: Some((chunk_size, grid)),
        }
    }

    /// The chunk grid computed during serialization, as
    /// `(chunk_size, chunks)` — `None` unless built via
    /// [`SerializedCheckpoint::new_chunked`]. Chunk 0 is the header;
    /// the chunks tile the stream contiguously in order.
    pub fn chunk_grid(&self) -> Option<(u64, &[ChunkDigest])> {
        self.chunk_grid.as_ref().map(|(cs, g)| (*cs, g.as_slice()))
    }

    /// Total length of the logical stream (header + data).
    pub fn total_len(&self) -> u64 {
        self.header_bytes.len() as u64 + self.data_len
    }

    /// Digest of the logical stream, for the checkpoint manifest.
    /// Matches [`crate::serialize::format::stream_digest_of`] over the
    /// assembled bytes.
    pub fn stream_digest(&self) -> u64 {
        self.stream_digest
    }

    /// Encoded header length (preamble + header JSON) in bytes.
    pub fn header_len(&self) -> u64 {
        self.header_bytes.len() as u64
    }

    /// Data-section length in bytes.
    pub fn data_len(&self) -> u64 {
        self.data_len
    }

    /// Emit stream bytes `[start, end)` to `out` in order. Pieces are
    /// the header slice plus payload slices of overlapping tensors; no
    /// intermediate stream buffer is built.
    pub fn emit_range(
        &self,
        start: u64,
        end: u64,
        out: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        assert!(start <= end && end <= self.total_len(), "bad range");
        let mut pos = start;
        // header overlap
        let hlen = self.header_bytes.len() as u64;
        if pos < hlen && pos < end {
            let stop = end.min(hlen);
            out(&self.header_bytes[pos as usize..stop as usize])?;
            pos = stop;
        }
        if pos >= end {
            return Ok(());
        }
        // payload overlap: walk tensors; offsets are stream-relative
        let mut toff = hlen;
        for t in self.snapshot.iter() {
            let tlen = t.nbytes();
            let tend = toff + tlen;
            if tend > pos && toff < end {
                let s = pos.max(toff) - toff;
                let e = end.min(tend) - toff;
                out(&t.data[s as usize..e as usize])?;
                pos = end.min(tend);
                if pos >= end {
                    break;
                }
            }
            toff = tend;
        }
        debug_assert_eq!(pos, end, "range not fully emitted");
        Ok(())
    }

    /// Write stream bytes `[start, end)` to a sink, coalescing small
    /// pieces through a pending queue (§4.1's aggregation applied at the
    /// serializer boundary).
    pub fn write_range_to(&self, start: u64, end: u64, sink: &mut dyn Sink) -> Result<()> {
        let mut queue = PendingQueue::new(COALESCE);
        self.emit_range(start, end, &mut |piece| {
            queue.append(piece, |block| sink.write(block))
        })?;
        queue.drain(|block| sink.write(block))
    }

    /// Write several stream ranges back to back through **one** pending
    /// queue — the segment-store write of [`crate::checkpoint::delta`]:
    /// non-adjacent dirty chunks coalesce into the large sequential
    /// writes the NVMe path wants, instead of one small file each.
    pub fn write_ranges_to(&self, ranges: &[(u64, u64)], sink: &mut dyn Sink) -> Result<()> {
        let mut queue = PendingQueue::new(COALESCE);
        for &(start, end) in ranges {
            self.emit_range(start, end, &mut |piece| {
                queue.append(piece, |block| sink.write(block))
            })?;
        }
        queue.drain(|block| sink.write(block))
    }

    /// Materialize the whole stream (tests / small checkpoints only).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.total_len() as usize);
        self.emit_range(0, self.total_len(), &mut |p| {
            out.extend_from_slice(p);
            Ok(())
        })
        .unwrap();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serialize::format::FormatHeader;
    use crate::tensor::{DType, Tensor, TensorStore};
    use crate::util::rng::Rng;

    fn store(seed: u64, sizes: &[usize]) -> TensorStore {
        let mut rng = Rng::new(seed);
        let mut s = TensorStore::new();
        for (i, &n) in sizes.iter().enumerate() {
            let mut data = vec![0u8; n];
            rng.fill_bytes(&mut data);
            s.push(Tensor::new(&format!("t{i}"), DType::U8, vec![n], data).unwrap())
                .unwrap();
        }
        s
    }

    #[test]
    fn stream_decodes_back() {
        let s = store(1, &[64, 3, 4096]);
        let mut extra = BTreeMap::new();
        extra.insert("step".into(), Json::Int(7));
        let ser = SerializedCheckpoint::new(&s, extra);
        let bytes = ser.to_bytes();
        assert_eq!(bytes.len() as u64, ser.total_len());
        let (hdr, consumed) = FormatHeader::decode(&bytes).unwrap();
        assert_eq!(hdr.data_len, 64 + 3 + 4096);
        assert_eq!(hdr.extra["step"], Json::Int(7));
        assert_eq!(bytes.len() - consumed, hdr.data_len as usize);
    }

    #[test]
    fn range_emission_matches_full_stream() {
        let s = store(2, &[100, 1, 777, 4096, 13]);
        let ser = SerializedCheckpoint::new(&s, BTreeMap::new());
        let full = ser.to_bytes();
        let total = ser.total_len();
        for (start, end) in [
            (0, total),
            (0, 1),
            (total - 1, total),
            (50, 60),
            (0, ser.header_len()),
            (ser.header_len(), total),
            (ser.header_len() + 99, ser.header_len() + 102), // spans t0/t1
            (7, 7), // empty
        ] {
            let mut got = Vec::new();
            ser.emit_range(start, end, &mut |p| {
                got.extend_from_slice(p);
                Ok(())
            })
            .unwrap();
            assert_eq!(got, full[start as usize..end as usize], "[{start},{end})");
        }
    }

    #[test]
    fn stream_digest_matches_assembled_stream() {
        let s = store(5, &[1000, 1, 4096]);
        let ser = SerializedCheckpoint::new(&s, BTreeMap::new());
        let bytes = ser.to_bytes();
        assert_eq!(
            ser.stream_digest(),
            crate::serialize::format::stream_digest_of(&bytes).unwrap(),
            "single-pass digest must equal the digest of the assembled stream"
        );
    }

    #[test]
    fn chunked_serialization_grid_matches_slice_checksums() {
        use crate::serialize::format::checksum64_slice;
        const CS: u64 = 1024;
        let s = store(9, &[3000, 17, 2048]);
        let ser = SerializedCheckpoint::new_chunked(&s, BTreeMap::new(), CS);
        let bytes = ser.to_bytes();
        let (cs, grid) = ser.chunk_grid().unwrap();
        assert_eq!(cs, CS);
        // chunk 0 is the whole header; the rest tile the data section
        assert_eq!(grid[0].len, ser.header_len());
        assert_eq!(grid.len(), 1 + (ser.data_len() as usize).div_ceil(CS as usize));
        let mut off = 0usize;
        for (i, ch) in grid.iter().enumerate() {
            let end = off + ch.len as usize;
            assert_eq!(ch.hash, checksum64_slice(&bytes[off..end]), "chunk {i}");
            off = end;
        }
        assert_eq!(off, bytes.len());
        // digest identical to the unchunked constructor's
        let plain = SerializedCheckpoint::new(&s, BTreeMap::new());
        assert_eq!(ser.stream_digest(), plain.stream_digest());
        assert!(plain.chunk_grid().is_none());
    }

    #[test]
    fn write_ranges_concatenates_in_order() {
        struct VecSink(Vec<u8>);
        impl crate::io::Sink for VecSink {
            fn write(&mut self, data: &[u8]) -> Result<()> {
                self.0.extend_from_slice(data);
                Ok(())
            }
            fn finish(self: Box<Self>) -> Result<crate::io::engine::WriteStats> {
                Ok(Default::default())
            }
        }
        let s = store(3, &[5000, 300]);
        let ser = SerializedCheckpoint::new(&s, BTreeMap::new());
        let full = ser.to_bytes();
        let total = ser.total_len();
        let ranges = [(0u64, 100u64), (4000, 4500), (total - 7, total)];
        let mut sink = VecSink(Vec::new());
        ser.write_ranges_to(&ranges, &mut sink).unwrap();
        let mut expect = Vec::new();
        for (s0, e0) in ranges {
            expect.extend_from_slice(&full[s0 as usize..e0 as usize]);
        }
        assert_eq!(sink.0, expect);
    }

    #[test]
    fn empty_store_serializes() {
        let ser = SerializedCheckpoint::new(&TensorStore::new(), BTreeMap::new());
        let bytes = ser.to_bytes();
        let (hdr, consumed) = FormatHeader::decode(&bytes).unwrap();
        assert_eq!(hdr.data_len, 0);
        assert_eq!(consumed as u64, ser.total_len());
    }

    #[test]
    fn prop_any_partition_reassembles() {
        crate::prop::forall("serialized ranges tile the stream", 48, |g| {
            let ntensors = g.usize(0, 5);
            let sizes: Vec<usize> = (0..ntensors).map(|_| g.usize(0, 2000)).collect();
            let s = store(g.u64(0, u64::MAX), &sizes);
            let ser = SerializedCheckpoint::new(&s, BTreeMap::new());
            let full = ser.to_bytes();
            // random cut points
            let total = ser.total_len();
            let mut cuts: Vec<u64> = (0..g.usize(0, 6)).map(|_| g.u64(0, total)).collect();
            cuts.push(0);
            cuts.push(total);
            cuts.sort();
            let mut assembled = Vec::new();
            for w in cuts.windows(2) {
                ser.emit_range(w[0], w[1], &mut |p| {
                    assembled.extend_from_slice(p);
                    Ok(())
                })
                .unwrap();
            }
            assembled == full
        });
    }
}
