//! Checkpoint reader: parse + verify a serialized checkpoint stream and
//! reconstruct the [`TensorStore`].
//!
//! Verification is **folded** into the parse: reconstructing tensors
//! already requires one pass over the data section, and that same pass
//! produces the data digest. [`parse_verified`] additionally combines
//! it with the (cheap) header digest into the manifest's composite
//! stream digest — so a restore makes exactly one post-assembly pass
//! over the stream instead of a digest pass *plus* a parse pass.

use std::path::Path;

use crate::serialize::format::{checksum64_slice, combine_digests, FormatHeader};
use crate::tensor::{Tensor, TensorMeta, TensorStore};
use crate::{Error, Result};

/// Parse a full checkpoint stream from memory; verifies the data digest.
pub fn parse_checkpoint(bytes: &[u8]) -> Result<(TensorStore, FormatHeader)> {
    parse_inner(bytes, None)
}

/// Like [`parse_checkpoint`], additionally verifying the manifest's
/// composite stream digest (header ‖ data halves, see
/// [`crate::serialize::format::stream_digest_of`]) — folded into the
/// parse's single data pass, not a separate pass over the stream.
pub fn parse_verified(
    bytes: &[u8],
    stream_digest: u64,
) -> Result<(TensorStore, FormatHeader)> {
    parse_inner(bytes, Some(stream_digest))
}

fn parse_inner(
    bytes: &[u8],
    expect_stream_digest: Option<u64>,
) -> Result<(TensorStore, FormatHeader)> {
    let (header, data_start) = FormatHeader::decode(bytes)?;
    let data = bytes
        .get(data_start..)
        .ok_or_else(|| Error::Format("missing data section".into()))?;
    if data.len() as u64 != header.data_len {
        return Err(Error::Format(format!(
            "data section is {} bytes, header says {}",
            data.len(),
            header.data_len
        )));
    }
    let digest = checksum64_slice(data);
    if digest != header.digest {
        return Err(Error::Format(format!(
            "digest mismatch: computed {digest:#x}, header {:#x}",
            header.digest
        )));
    }
    if let Some(expect) = expect_stream_digest {
        // combine with the header half: same composite the writer's
        // single-pass digest produced for the manifest
        let got = combine_digests(checksum64_slice(&bytes[..data_start]), digest);
        if got != expect {
            return Err(Error::Format(format!(
                "stream digest mismatch: computed {got:#x}, manifest {expect:#x}"
            )));
        }
    }
    TensorMeta::check_contiguous(&header.tensors)?;
    let mut store = TensorStore::new();
    for meta in &header.tensors {
        let start = meta.offset as usize;
        let end = start + meta.nbytes() as usize;
        if end > data.len() {
            return Err(Error::Format(format!("tensor {} exceeds data section", meta.name)));
        }
        store.push(Tensor::new(
            &meta.name,
            meta.dtype,
            meta.shape.clone(),
            data[start..end].to_vec(),
        )?)?;
    }
    Ok((store, header))
}

/// Read + parse a checkpoint file.
pub fn read_checkpoint(path: &Path) -> Result<(TensorStore, FormatHeader)> {
    let bytes = std::fs::read(path)?;
    parse_checkpoint(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serialize::writer::SerializedCheckpoint;
    use crate::tensor::DType;
    use crate::util::json::Json;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn sample_store() -> TensorStore {
        let mut rng = Rng::new(7);
        let mut s = TensorStore::new();
        let mut w = vec![0u8; 4 * 100];
        rng.fill_bytes(&mut w);
        s.push(Tensor::new("w", DType::F32, vec![10, 10], w).unwrap()).unwrap();
        s.push(Tensor::from_i32("step", vec![], &[42]).unwrap()).unwrap();
        let mut h = vec![0u8; 2 * 33];
        rng.fill_bytes(&mut h);
        s.push(Tensor::new("half", DType::F16, vec![33], h).unwrap()).unwrap();
        s
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let store = sample_store();
        let mut extra = BTreeMap::new();
        extra.insert("lr".into(), Json::Float(0.001));
        let ser = SerializedCheckpoint::new(&store, extra);
        let (loaded, header) = parse_checkpoint(&ser.to_bytes()).unwrap();
        assert!(loaded.content_eq(&store));
        assert_eq!(header.extra["lr"], Json::Float(0.001));
    }

    #[test]
    fn parse_verified_checks_the_composite_stream_digest() {
        let store = sample_store();
        let ser = SerializedCheckpoint::new(&store, BTreeMap::new());
        let bytes = ser.to_bytes();
        // the writer's single-pass digest verifies through the parse
        let (loaded, _) = parse_verified(&bytes, ser.stream_digest()).unwrap();
        assert!(loaded.content_eq(&store));
        // a wrong manifest digest is caught even though header and data
        // are internally consistent
        match parse_verified(&bytes, ser.stream_digest() ^ 1) {
            Err(Error::Format(msg)) => assert!(msg.contains("stream digest"), "{msg}"),
            other => panic!("expected stream-digest error, got {other:?}"),
        }
    }

    #[test]
    fn detects_payload_corruption() {
        let store = sample_store();
        let ser = SerializedCheckpoint::new(&store, BTreeMap::new());
        let mut bytes = ser.to_bytes();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        match parse_checkpoint(&bytes) {
            Err(Error::Format(msg)) => assert!(msg.contains("digest"), "{msg}"),
            other => panic!("expected digest error, got {other:?}"),
        }
    }

    #[test]
    fn detects_truncation() {
        let store = sample_store();
        let ser = SerializedCheckpoint::new(&store, BTreeMap::new());
        let bytes = ser.to_bytes();
        for cut in [bytes.len() - 1, bytes.len() - 100, 20] {
            assert!(parse_checkpoint(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::io::engine::scratch_dir("reader").unwrap();
        let path = dir.join("ck.fpck");
        let store = sample_store();
        let ser = SerializedCheckpoint::new(&store, BTreeMap::new());
        std::fs::write(&path, ser.to_bytes()).unwrap();
        let (loaded, _) = read_checkpoint(&path).unwrap();
        assert!(loaded.content_eq(&store));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
