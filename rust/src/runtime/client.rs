//! PJRT client wrapper: HLO text → compiled executable → execution.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO **text** is the
//! interchange format (jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects in proto form; the text parser reassigns
//! ids). All entrypoints are lowered with `return_tuple=True`, so every
//! execution result is a tuple literal.

use std::path::Path;

use crate::runtime::artifacts::{ArtifactManifest, EntrySpec};
use crate::{Error, Result};

/// Owning wrapper around the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Config(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    /// Load an entrypoint from the artifact manifest.
    pub fn load_entry(&self, manifest: &ArtifactManifest, entry: &EntrySpec) -> Result<Executable> {
        self.load_hlo(&manifest.hlo_path(entry))
    }
}

/// A compiled computation ready to run.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Source HLO path (for error messages).
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    ///
    /// Inputs are transferred via `buffer_from_host_literal` into
    /// Rust-owned `PjRtBuffer`s and run through `execute_b`. Do NOT use
    /// the crate's `execute::<Literal>` here: its C++ shim leaks every
    /// input device buffer (`buffer.release()` with no matching free),
    /// ~250 MB/iteration for the gpt20m train step — it OOM-killed a
    /// 300-step run at 36 GB RSS before this was fixed.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let client = self.exe.client();
        let buffers = inputs
            .iter()
            .map(|l| client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        let out = result[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }
}

/// Build a rank-1 f32 literal.
pub fn lit_f32(values: &[f32]) -> xla::Literal {
    xla::Literal::vec1(values)
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(values: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(dims)?)
}

/// Extract a f32 vector from a literal (converting from F16 if needed).
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    match lit.ty()? {
        xla::ElementType::F32 => Ok(lit.to_vec::<f32>()?),
        other => {
            let conv = lit.convert(xla::ElementType::F32.primitive_type())?;
            let _ = other;
            Ok(conv.to_vec::<f32>()?)
        }
    }
}

/// Extract the scalar f32 (e.g. the loss output).
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn fused_adam_unit_hlo_matches_rust_reference() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // Parse the units table from the manifest JSON directly.
        let text = std::fs::read_to_string(artifacts_dir().join("manifest.json")).unwrap();
        let manifest = Json::parse(&text).unwrap();
        let unit = manifest.get("units").unwrap().get("fused_adam_unit").unwrap();
        let n = unit.get("n").unwrap().as_usize().unwrap();
        let file = unit.get("file").unwrap().as_str().unwrap();

        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo(&artifacts_dir().join(file)).unwrap();

        let mut rng = crate::util::rng::Rng::new(3);
        let theta: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let m = vec![0f32; n];
        let v = vec![0f32; n];
        let out = exe
            .run(&[
                lit_f32(&theta),
                lit_f32(&g),
                lit_f32(&m),
                lit_f32(&v),
                xla::Literal::scalar(1f32),
            ])
            .unwrap();
        assert_eq!(out.len(), 3);
        let theta2 = to_f32_vec(&out[0]).unwrap();
        // Rust-side Adam reference (step 1, zero moments):
        // mhat = g, vhat = g^2 → theta - lr * g / (|g| + eps)
        for i in 0..n {
            let expect = theta[i] - 1e-3 * g[i] / (g[i].abs() + 1e-8);
            assert!(
                (theta2[i] - expect).abs() < 1e-5,
                "i={i}: {} vs {expect}",
                theta2[i]
            );
        }
    }

    #[test]
    fn pack_fp16_hlo_matches_rust_f16() {
        if !have_artifacts() {
            return;
        }
        let m = ArtifactManifest::load(&artifacts_dir()).unwrap();
        let tiny = m.config("tiny").unwrap();
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_entry(&m, &tiny.entrypoints["pack_fp16"]).unwrap();
        let n = tiny.n_padded;
        let mut rng = crate::util::rng::Rng::new(9);
        let theta: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0) as f32).collect();
        let out = exe.run(&[lit_f32(&theta)]).unwrap();
        let packed = to_f32_vec(&out[0]).unwrap(); // f16 → f32
        // must equal our Rust f16 codec applied to theta
        for i in (0..n).step_by(97) {
            let expect =
                crate::util::f16::f16_bits_to_f32(crate::util::f16::f32_to_f16_bits(theta[i]));
            assert_eq!(packed[i], expect, "i={i}");
        }
    }

    #[test]
    fn eval_loss_runs_and_is_near_uniform() {
        if !have_artifacts() {
            return;
        }
        let m = ArtifactManifest::load(&artifacts_dir()).unwrap();
        let tiny = m.config("tiny").unwrap();
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_entry(&m, &tiny.entrypoints["eval_loss"]).unwrap();
        let n = tiny.n_padded;
        // zero params → logits all equal → loss == ln(vocab)
        let theta = vec![0f32; n];
        let toks: Vec<i32> = (0..tiny.batch * (tiny.seq + 1))
            .map(|i| (i % tiny.vocab) as i32)
            .collect();
        let out = exe
            .run(&[
                lit_f32(&theta),
                lit_i32(&toks, &[tiny.batch as i64, (tiny.seq + 1) as i64]).unwrap(),
            ])
            .unwrap();
        let loss = to_f32_scalar(&out[0]).unwrap();
        let expect = (tiny.vocab as f32).ln();
        assert!((loss - expect).abs() < 0.05, "loss={loss} expect={expect}");
    }
}
