//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the Rust hot path. Python never runs here — `make artifacts` is the
//! only compile-path step.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactManifest, EntrySpec, ModelArtifact, TensorEntry};
pub use client::{Executable, Runtime};
