//! `artifacts/manifest.json` — the Python→Rust interchange contract.
//!
//! Produced once by `python -m compile.aot` (see `python/compile/aot.py`)
//! and parsed here; it carries the model configs, flat-parameter layout
//! (the serialized-tensor metadata table), and the HLO entrypoint
//! signatures the runtime validates inputs against.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::tensor::DType;
use crate::util::json::Json;
use crate::{Error, Result};

/// Manifest schema version this build understands.
pub const SUPPORTED_VERSION: i64 = 1;

/// One logical tensor inside the flat parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorEntry {
    /// Tensor name.
    pub name: String,
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Element offset within the flat vector.
    pub offset: usize,
    /// Element count.
    pub size: usize,
}

/// One HLO entrypoint (train_step / eval_loss / pack_fp16 / units).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    /// HLO file name relative to the artifacts dir.
    pub file: String,
    /// Input signature: (name, dtype, shape) per argument.
    pub inputs: Vec<(String, DType, Vec<usize>)>,
    /// Output signature: (name, dtype, shape) per result.
    pub outputs: Vec<(String, DType, Vec<usize>)>,
}

/// One lowered model config.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    /// Config name (tiny/small/gpt20m/...).
    pub name: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden dimension.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layer: usize,
    /// Attention head count.
    pub n_head: usize,
    /// Sequence length.
    pub seq: usize,
    /// Batch size the HLOs were lowered at.
    pub batch: usize,
    /// Real parameter count.
    pub n_params: usize,
    /// Parameter count padded to the Pallas grid.
    pub n_padded: usize,
    /// Flat-vector layout of every logical tensor.
    pub tensors: Vec<TensorEntry>,
    /// Lowered HLO entrypoints by name.
    pub entrypoints: BTreeMap<String, EntrySpec>,
}

/// Adam hyperparameters baked into the train_step HLO.
#[derive(Debug, Clone, Copy)]
pub struct AdamHyper {
    /// Learning rate.
    pub lr: f64,
    /// First-moment decay.
    pub beta1: f64,
    /// Second-moment decay.
    pub beta2: f64,
    /// Denominator epsilon.
    pub eps: f64,
}

/// The whole parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Flat-parameter alignment (the Pallas grid unit).
    pub param_align: usize,
    /// Adam hyperparameters baked into the HLOs.
    pub adam: AdamHyper,
    /// Model configs by name.
    pub configs: BTreeMap<String, ModelArtifact>,
}

fn parse_specs(v: &Json) -> Result<Vec<(String, DType, Vec<usize>)>> {
    v.as_array()?
        .iter()
        .map(|s| {
            let shape = s
                .get("shape")?
                .as_array()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            Ok((
                s.get("name")?.as_str()?.to_string(),
                DType::parse(s.get("dtype")?.as_str()?)?,
                shape,
            ))
        })
        .collect()
}

fn parse_entry(v: &Json) -> Result<EntrySpec> {
    Ok(EntrySpec {
        file: v.get("file")?.as_str()?.to_string(),
        inputs: parse_specs(v.get("inputs")?)?,
        outputs: parse_specs(v.get("outputs")?)?,
    })
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Config(format!(
                "{}: {e} — run `make artifacts` first",
                path.display()
            ))
        })?;
        let v = Json::parse(&text)?;
        let version = v.get("version")?.as_i64()?;
        if version != SUPPORTED_VERSION {
            return Err(Error::Config(format!("manifest version {version} unsupported")));
        }
        let adam = v.get("adam")?;
        let adam = AdamHyper {
            lr: adam.get("lr")?.as_f64()?,
            beta1: adam.get("beta1")?.as_f64()?,
            beta2: adam.get("beta2")?.as_f64()?,
            eps: adam.get("eps")?.as_f64()?,
        };
        let mut configs = BTreeMap::new();
        for (name, c) in v.get("configs")?.as_object()? {
            let model = c.get("model")?;
            let tensors = c
                .get("tensors")?
                .as_array()?
                .iter()
                .map(|t| {
                    Ok(TensorEntry {
                        name: t.get("name")?.as_str()?.to_string(),
                        shape: t
                            .get("shape")?
                            .as_array()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                        offset: t.get("offset")?.as_usize()?,
                        size: t.get("size")?.as_usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let mut entrypoints = BTreeMap::new();
            for (ep_name, ep) in c.get("entrypoints")?.as_object()? {
                entrypoints.insert(ep_name.clone(), parse_entry(ep)?);
            }
            configs.insert(
                name.clone(),
                ModelArtifact {
                    name: name.clone(),
                    vocab: model.get("vocab")?.as_usize()?,
                    d_model: model.get("d_model")?.as_usize()?,
                    n_layer: model.get("n_layer")?.as_usize()?,
                    n_head: model.get("n_head")?.as_usize()?,
                    seq: model.get("seq")?.as_usize()?,
                    batch: model.get("batch")?.as_usize()?,
                    n_params: c.get("n_params")?.as_usize()?,
                    n_padded: c.get("n_padded")?.as_usize()?,
                    tensors,
                    entrypoints,
                },
            );
        }
        let m = ArtifactManifest {
            dir: dir.to_path_buf(),
            param_align: v.get("param_align")?.as_usize()?,
            adam,
            configs,
        };
        m.validate()?;
        Ok(m)
    }

    /// Default artifacts directory: $FASTPERSIST_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("FASTPERSIST_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Look a model config up by name.
    pub fn config(&self, name: &str) -> Result<&ModelArtifact> {
        self.configs.get(name).ok_or_else(|| {
            Error::Config(format!(
                "model config {name:?} not in manifest (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// Absolute path of an entrypoint's HLO file.
    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.dir.join(&entry.file)
    }

    fn validate(&self) -> Result<()> {
        for (name, c) in &self.configs {
            if c.n_padded % self.param_align != 0 {
                return Err(Error::Config(format!("{name}: n_padded not aligned")));
            }
            let mut off = 0usize;
            for t in &c.tensors {
                if t.offset != off {
                    return Err(Error::Config(format!(
                        "{name}/{}: offset {} expected {off}",
                        t.name, t.offset
                    )));
                }
                let elems: usize = t.shape.iter().product();
                if elems != t.size {
                    return Err(Error::Config(format!("{name}/{}: shape/size mismatch", t.name)));
                }
                off += t.size;
            }
            if off != c.n_params {
                return Err(Error::Config(format!(
                    "{name}: tensor table covers {off} of {} params",
                    c.n_params
                )));
            }
            for ep in ["train_step", "eval_loss", "pack_fp16"] {
                if !c.entrypoints.contains_key(ep) {
                    return Err(Error::Config(format!("{name}: missing entrypoint {ep}")));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // repo-root artifacts (tests run from the crate root)
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&artifacts_dir()).unwrap();
        assert!(m.configs.contains_key("tiny"));
        let tiny = m.config("tiny").unwrap();
        assert_eq!(tiny.entrypoints["train_step"].inputs.len(), 5);
        assert_eq!(tiny.entrypoints["train_step"].outputs.len(), 4);
        assert!(m.hlo_path(&tiny.entrypoints["train_step"]).exists());
        assert!((m.adam.lr - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn tensor_table_is_contiguous_in_real_manifest() {
        if !have_artifacts() {
            return;
        }
        let m = ArtifactManifest::load(&artifacts_dir()).unwrap();
        for c in m.configs.values() {
            let total: usize = c.tensors.iter().map(|t| t.size).sum();
            assert_eq!(total, c.n_params, "{}", c.name);
            assert!(c.n_padded >= c.n_params);
        }
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactManifest::load(Path::new("/nonexistent-path")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
