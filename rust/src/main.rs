//! `fastpersist` — CLI for the FastPersist reproduction.
//!
//! Subcommands:
//!   repro <exp>   regenerate a paper table/figure (fig1..fig12, table1, all)
//!   train         run real PJRT training with checkpointing
//!   resume        resume training from the latest checkpoint
//!   ckpt-write    one-off checkpoint write microbenchmark
//!   info          show artifact/model information

use std::path::PathBuf;
use std::process::ExitCode;

use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::figures;
use fastpersist::io::device::DeviceMap;
use fastpersist::io::engine::{EngineKind, IoBackend, IoConfig};
use fastpersist::runtime::artifacts::ArtifactManifest;
use fastpersist::training::looper::{CkptRunMode, Trainer, TrainerConfig};
use fastpersist::util::bytes::human;
use fastpersist::util::cli::ArgSpec;
use fastpersist::util::table::Table;
use fastpersist::{Error, Result};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(Error::Config(msg)) => {
            eprintln!("{msg}");
            ExitCode::from(2)
        }
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "fastpersist — FastPersist: accelerating model checkpointing (reproduction)\n\n\
     usage: fastpersist <command> [options]\n\n\
     commands:\n\
       repro <exp> [--fast]   regenerate paper experiments:\n\
                              fig1 fig2 table1 fig7 fig8 fig9 fig10 fig11 fig12 all\n\
       train [opts]           real PJRT training with per-iteration checkpointing\n\
       resume [opts]          resume training from the latest checkpoint\n\
       ckpt-write [opts]      checkpoint-write microbenchmark on local disk\n\
       info                   artifact manifest / model zoo summary\n\n\
     run with `<command> --help` for per-command options\n"
        .to_string()
}

fn dispatch(mut args: Vec<String>) -> Result<()> {
    if args.is_empty() {
        return Err(Error::Config(usage()));
    }
    let cmd = args.remove(0);
    match cmd.as_str() {
        "repro" => cmd_repro(args),
        "train" => cmd_train(args, false),
        "resume" => cmd_train(args, true),
        "ckpt-write" => cmd_ckpt_write(args),
        "info" => cmd_info(),
        "-h" | "--help" | "help" => Err(Error::Config(usage())),
        other => Err(Error::Config(format!("unknown command {other:?}\n\n{}", usage()))),
    }
}

fn cmd_repro(args: Vec<String>) -> Result<()> {
    let spec = ArgSpec::new("fastpersist repro", "regenerate paper tables/figures")
        .flag("fast", "smaller sweeps for CI-speed runs");
    let parsed = spec.parse(args)?;
    let fast = parsed.has("fast");
    let which = parsed
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    match which {
        "fig1" => figures::fig1::run(),
        "fig2" => figures::fig2::run(),
        "table1" => figures::table1::run(),
        "fig7" => figures::fig7::run(fast),
        "fig8" => figures::fig8::run(),
        "fig9" => figures::fig9::run(),
        "fig10" => figures::fig10::run(),
        "fig11" => figures::fig11::run(),
        "fig12" => figures::fig12::run(),
        "all" => figures::run_all(fast),
        other => Err(Error::Config(format!("unknown experiment {other:?}"))),
    }
}

fn train_spec(name: &'static str) -> ArgSpec {
    ArgSpec::new(name, "real PJRT training with FastPersist checkpointing")
        .opt("model", "model config (tiny/small/gpt20m/gpt100m)", "gpt20m")
        .opt("steps", "training iterations", "100")
        .opt("ckpt-every", "checkpoint every n iterations (0=off)", "1")
        .opt("ckpt-dir", "checkpoint directory", "ckpts")
        .opt("mode", "none|baseline|sync|pipelined|lazy", "pipelined")
        .flag("ckpt-lazy", "shorthand for --mode lazy (capture/flush split)")
        .opt("strategy", "rank0|replica|socket|node|fixedN", "replica")
        .opt("ckpt", "full | delta | deltaN (incremental, compact after N; \
                       --strategy applies to full only)", "full")
        .opt("segment-bytes", "target payload bytes per delta segment file \
                               (>= 4 KiB)", "64MiB")
        .opt("ckpt-codec", "none | lz4 | qdelta per-chunk codec between \
                            serialization and segment packing (lz4 = in-repo \
                            block compression; qdelta = quantized diffs vs the \
                            chunk's last stored bytes, exact raw restored at \
                            base/compaction)", "none")
        .opt("engine", "buffered|single|double", "double")
        .opt("io-backend", "sync | ring | auto drain-lane submission backend \
                            (ring batches queue-depth extents per syscall; auto \
                            probes and falls back to sync)", "auto")
        .opt("io-buf", "IO buffer size", "32MiB")
        .opt("queue-depth", "submission-queue depth per write (>= 1; 1 = single \
                             buffering, 2+ = double buffering)", "2")
        .opt("ckpt-staging", "lazy-mode staging budget: cap on captured-but-not-\
                              durable checkpoint bytes", "256MiB")
        .opt("ckpt-gens", "lazy-mode max generations in flight (1 = eager \
                           semantics)", "2")
        .opt("devices", "none | simN (N simulated SSDs) | dir,dir,...", "none")
        .opt("writers", "parallel DP writer threads", "2")
        .opt("ga", "gradient accumulation steps", "1")
        .opt("seed", "init/data seed", "0")
        .opt("keep-last", "checkpoints retained (0=all)", "3")
        .opt("gc-occupancy", "segment-GC rewrite threshold in [0,1]: demoted \
                              chunk stores below this live-byte occupancy are \
                              sparsely rewritten", "0.5")
        .opt("serve-cache-bytes", "resume-restore segment cache budget \
                                   (0 = restore without a cache)", "0")
        .opt("log-every", "progress print interval", "10")
}

/// Parse a `--devices` spec into a [`DeviceMap`]: `none`, `simN`
/// (N simulated SSDs under `base/devices`), or comma-separated mount
/// points.
fn parse_devices(spec: &str, base: &std::path::Path) -> Result<DeviceMap> {
    match spec {
        "" | "none" | "single" => Ok(DeviceMap::single()),
        sim if sim.starts_with("sim") => {
            let n: usize = sim[3..]
                .parse()
                .map_err(|_| Error::Config(format!("bad device spec {spec:?}")))?;
            DeviceMap::simulated(n, &base.join("devices"))
        }
        roots => DeviceMap::from_roots(roots.split(',').map(PathBuf::from).collect()),
    }
}

fn cmd_train(args: Vec<String>, resume: bool) -> Result<()> {
    let parsed = train_spec(if resume { "fastpersist resume" } else { "fastpersist train" })
        .parse(args)?;
    let manifest = ArtifactManifest::load(&ArtifactManifest::default_dir())?;
    let mut io = IoConfig::with_kind(EngineKind::parse(parsed.get("engine"))?);
    io.backend = IoBackend::parse(parsed.get("io-backend"))?;
    io.io_buf_size = parsed.get_size("io-buf")? as usize;
    let queue_depth = parsed.get_usize("queue-depth")?;
    if queue_depth == 0 {
        return Err(Error::Config(
            "--queue-depth must be at least 1 (1 = single buffering, 2+ overlaps \
             the drain of extent k with the staging of extent k+1)"
                .into(),
        ));
    }
    io.queue_depth = queue_depth;
    let ckpt_dir = PathBuf::from(parsed.get("ckpt-dir"));
    let devices = parse_devices(parsed.get("devices"), &ckpt_dir)?;
    let segment_bytes = parsed.get_size("segment-bytes")?;
    if segment_bytes < 4096 {
        return Err(Error::Config(format!(
            "--segment-bytes must be at least the 4 KiB alignment unit, got {segment_bytes} \
             (segments pack 4 KiB-aligned chunks; smaller segments cannot hold one)"
        )));
    }
    let cfg = TrainerConfig {
        model: parsed.get("model").to_string(),
        steps: parsed.get_usize("steps")? as u64,
        ckpt_every: parsed.get_usize("ckpt-every")? as u64,
        ckpt_dir,
        mode: if parsed.has("ckpt-lazy") {
            CkptRunMode::Lazy
        } else {
            CkptRunMode::parse(parsed.get("mode"))?
        },
        strategy: WriterStrategy::parse(parsed.get("strategy"))?,
        ckpt_strategy: fastpersist::checkpoint::delta::CheckpointStrategy::parse(
            parsed.get("ckpt"),
        )?,
        segment_bytes,
        ckpt_codec: fastpersist::checkpoint::codec::CodecKind::parse(parsed.get("ckpt-codec"))?,
        io,
        devices,
        dp_writers: parsed.get_usize("writers")?,
        grad_accum: parsed.get_usize("ga")? as u64,
        seed: parsed.get_usize("seed")? as u64,
        keep_last: parsed.get_usize("keep-last")?,
        lazy_staging_bytes: parsed.get_size("ckpt-staging")?,
        lazy_max_generations: parsed.get_usize("ckpt-gens")?,
        gc_occupancy: parsed.get_f64("gc-occupancy")?.clamp(0.0, 1.0),
        serve_cache_bytes: parsed.get_size("serve-cache-bytes")?,
        log_every: parsed.get_usize("log-every")? as u64,
    };
    let mut trainer = if resume {
        let t = Trainer::resume(&manifest, cfg)?;
        match &t.restore {
            // the restore's read-path accounting, symmetric with the
            // write-job/fsync metrics printed after the run
            Some(r) => println!(
                "resumed at step {}: restored {} in {} read jobs \
                 ({} runs, {} coalesced chunk reads, {} preads) — {:.2} GB/s \
                 (checkpoint written via {} submission)",
                t.state.step,
                human(r.total_bytes),
                r.stats.jobs,
                r.stats.runs,
                r.stats.coalesced,
                r.stats.preads,
                r.gbps(),
                r.io_backend.as_deref().unwrap_or("pre-v6/unknown"),
            ),
            None => println!("resumed at step {}", t.state.step),
        }
        let (hits, misses) =
            (t.recorder.total("ckpt_cache_hits"), t.recorder.total("ckpt_cache_misses"));
        if hits + misses > 0.0 {
            println!(
                "serve cache: {} hits / {} misses ({} budget)",
                hits as u64,
                misses as u64,
                human(t.cfg.serve_cache_bytes),
            );
        }
        t
    } else {
        Trainer::new(&manifest, cfg)?
    };
    println!(
        "training {} ({} params, ckpt {} per iteration, mode {:?})",
        trainer.cfg.model,
        trainer.state.artifact.n_params,
        human(trainer.state.checkpoint_bytes()),
        trainer.cfg.mode,
    );
    let final_loss = trainer.run()?;
    let r = &trainer.recorder;
    println!("\ndone: {} steps, final loss {final_loss:.4}", trainer.state.step);
    println!(
        "iter p50 {:>8.1} ms | fb {:>8.1} ms | opt {:>6.1} ms | stall total {:.3} s | ckpts {}",
        r.summary("iter_s").p50 * 1e3,
        r.summary("fb_s").p50 * 1e3,
        r.summary("opt_s").p50 * 1e3,
        trainer.total_stall(),
        r.counter("ckpts"),
    );
    let written = r.total("ckpt_written_bytes");
    if written > 0.0 {
        println!(
            "ckpt bytes written {} total ({} per full snapshot) — strategy {}",
            human(written as u64),
            human(trainer.state.checkpoint_bytes()),
            trainer.cfg.ckpt_strategy.name(),
        );
    }
    let bytes_raw = r.total("ckpt_bytes_raw");
    if bytes_raw > 0.0
        && trainer.cfg.ckpt_codec != fastpersist::checkpoint::codec::CodecKind::None
    {
        // the codec ledger: stored/raw is the achieved ratio (1.0 means
        // the benefit gate kept everything raw), encode is CPU time
        // spent in the codec stage
        let bytes_enc = r.total("ckpt_bytes_encoded");
        println!(
            "ckpt codec {}: {} raw -> {} stored ({:.2}x ratio), encode {:.3} s",
            trainer.cfg.ckpt_codec.name(),
            human(bytes_raw as u64),
            human(bytes_enc as u64),
            bytes_enc / bytes_raw,
            r.total("ckpt_encode_s"),
        );
    }
    let jobs = r.total("ckpt_write_jobs");
    if jobs > 0.0 {
        println!(
            "ckpt write jobs {:.0} total ({:.1}/ckpt), fsyncs {:.0} total \
             (jobs are segments under --ckpt delta, partitions under full)",
            jobs,
            r.summary("ckpt_write_jobs").mean,
            r.total("ckpt_fsyncs"),
        );
        let direct_extents = r.total("ckpt_direct_extents");
        println!(
            "ckpt O_DIRECT extents {:.0}, bounce bytes {} — {}",
            direct_extents,
            human(r.total("ckpt_bounce_bytes") as u64),
            if direct_extents > 0.0 {
                "direct path engaged"
            } else {
                "buffered fallback (probe rejected O_DIRECT or durability off)"
            },
        );
        // Which submission path drained the lanes: batched_submissions
        // is zero end to end on the sync backend, non-zero proves the
        // ring path issued one syscall per queue-depth batch.
        let batched = r.total("ckpt_batched_submissions");
        println!(
            "ckpt submit backend {}: {:.0} batched submissions, {:.0} max sqes/submit, \
             {:.0} completions reaped — {}",
            trainer.io_runtime().submit_backend_name(&trainer.cfg.ckpt_dir),
            batched,
            r.summary("ckpt_sqes_per_submit_max").max,
            r.total("ckpt_completions_reaped"),
            if batched > 0.0 {
                "ring path engaged"
            } else {
                "per-extent sync submission"
            },
        );
    }
    let drain_total = r.total("drain_s");
    if drain_total > 0.0 {
        // the lazy split's ledger: trainer-side stall (capture copy +
        // staged backpressure) vs helper-side flush time that ran
        // concurrently with compute
        let iter_total = r.total("iter_s");
        println!(
            "lazy overlap: stall {:.3} s (capture {:.3} s + backpressure {:.3} s) vs \
             concurrent drain {:.3} s — {:.1}% of step time stalled",
            trainer.total_stall(),
            r.total("ckpt_capture_s"),
            r.total("ckpt_backpressure_s"),
            drain_total,
            if iter_total > 0.0 { trainer.total_stall() / iter_total * 100.0 } else { 0.0 },
        );
    }
    let lanes = trainer.io_runtime().drain_lane_stats();
    let submitted: u64 = lanes.iter().map(|l| l.submissions).sum();
    if submitted > 0 {
        let busy: f64 = lanes.iter().map(|l| l.busy.as_secs_f64()).sum();
        let max_queued = lanes.iter().map(|l| l.max_queued).max().unwrap_or(0);
        println!(
            "drain lanes {}: {} submissions, busy {:.3} s total, max queued/lane {}",
            lanes.len(),
            submitted,
            busy,
            max_queued,
        );
    }
    let read_bytes = r.total("ckpt_read_bytes");
    if read_bytes > 0.0 {
        let restore_s = r.total("ckpt_restore_s");
        println!(
            "ckpt read jobs {:.0}, coalesced chunk reads {:.0}, preads {:.0} — \
             restored {} at {:.2} GB/s",
            r.total("ckpt_read_jobs"),
            r.total("ckpt_read_coalesced"),
            r.total("ckpt_read_preads"),
            human(read_bytes as u64),
            fastpersist::util::bytes::gbps(read_bytes as u64, restore_s),
        );
        let decoded = r.total("ckpt_read_chunks_decoded");
        if decoded > 0.0 {
            println!(
                "ckpt decode: {:.0} encoded chunks ({}) decoded in {:.3} s",
                decoded,
                human(r.total("ckpt_read_bytes_encoded") as u64),
                r.total("ckpt_decode_s"),
            );
        }
    }
    Ok(())
}

fn cmd_ckpt_write(args: Vec<String>) -> Result<()> {
    let spec = ArgSpec::new("fastpersist ckpt-write", "checkpoint write microbenchmark")
        .opt("size", "checkpoint payload size", "256MiB")
        .opt("engine", "buffered|single|double", "double")
        .opt("io-backend", "sync | ring | auto drain-lane submission backend", "auto")
        .opt("io-buf", "IO buffer size", "32MiB")
        .opt("devices", "none | simN | dir,dir,...", "none")
        .opt("writers", "parallel writer threads", "1")
        .opt("reps", "repetitions (median reported)", "3")
        .flag("durable", "fsync + O_DIRECT (measures the raw device)");
    let parsed = spec.parse(args)?;
    let size = parsed.get_size("size")? as usize;
    let mut io = IoConfig::with_kind(EngineKind::parse(parsed.get("engine"))?);
    io.backend = IoBackend::parse(parsed.get("io-backend"))?;
    io.io_buf_size = parsed.get_size("io-buf")? as usize;
    if !parsed.has("durable") {
        io = io.microbench();
    }
    let writers = parsed.get_usize("writers")?.max(1);
    let reps = parsed.get_usize("reps")?.max(1);

    use fastpersist::checkpoint::engine::CheckpointEngine;
    use fastpersist::cluster::topology::RankPlacement;
    use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
    use fastpersist::tensor::{DType, Tensor, TensorStore};
    let mut store = TensorStore::new();
    store
        .push(Tensor::new("payload", DType::U8, vec![size], vec![0x5au8; size]).unwrap())
        .unwrap();
    let group: Vec<RankPlacement> = (0..writers)
        .map(|r| RankPlacement { rank: r, node: 0, socket: r % 2, local_gpu: r })
        .collect();
    let dir = fastpersist::io::engine::scratch_dir("ckpt-write")?;
    let devices = parse_devices(parsed.get("devices"), &dir)?;
    let defaults = IoRuntimeConfig::default();
    let runtime = std::sync::Arc::new(IoRuntime::new(IoRuntimeConfig {
        io,
        devices,
        // honor --writers as true write concurrency
        writer_threads: writers.max(defaults.writer_threads),
        ..defaults
    }));
    let engine = CheckpointEngine::with_runtime(runtime.clone(), WriterStrategy::AllReplicas);
    let mut times = Vec::new();
    let (mut batched, mut reaped, mut sqes_max) = (0u64, 0u64, 0u64);
    for i in 0..reps {
        let d = dir.join(format!("rep{i}"));
        let out = engine.write(&store, Default::default(), &d, &group)?;
        times.push(out.latency.as_secs_f64());
        batched += out.batched_submissions();
        reaped += out.completions_reaped();
        sqes_max = sqes_max.max(out.sqes_per_submit_max());
        let _ = std::fs::remove_dir_all(&d);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let t = times[times.len() / 2];
    println!(
        "{} via {} x{}: {:.1} ms median, {:.2} GB/s",
        human(size as u64),
        engine.io_cfg.kind.name(),
        writers,
        t * 1e3,
        size as f64 / 1e9 / t
    );
    println!(
        "submit backend {}: {batched} batched submissions, {sqes_max} max sqes/submit, \
         {reaped} completions reaped",
        runtime.submit_backend_name(&dir),
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("== model zoo (paper Table 2) ==");
    let mut t = Table::new(vec!["model", "params", "MP", "GBS", "ckpt size", "max DP"]);
    for m in fastpersist::model::MODEL_ZOO {
        t.row(vec![
            m.name.to_string(),
            format!("{:.1}B", m.params as f64 / 1e9),
            m.mp().to_string(),
            m.gbs.to_string(),
            human(m.ckpt_bytes),
            m.max_dp().to_string(),
        ]);
    }
    println!("{}", t.render());
    match ArtifactManifest::load(&ArtifactManifest::default_dir()) {
        Ok(manifest) => {
            println!("== AOT artifacts ({}) ==", manifest.dir.display());
            let mut t = Table::new(vec!["config", "params", "padded", "entrypoints"]);
            for (name, c) in &manifest.configs {
                t.row(vec![
                    name.clone(),
                    c.n_params.to_string(),
                    c.n_padded.to_string(),
                    c.entrypoints.keys().cloned().collect::<Vec<_>>().join(","),
                ]);
            }
            println!("{}", t.render());
        }
        Err(e) => println!("(artifacts not available: {e})"),
    }
    Ok(())
}
