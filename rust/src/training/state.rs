//! Training state: the flat parameter vector + Adam moments + step —
//! exactly the paper's checkpoint state (§2.1.3).
//!
//! The serialized form is mixed-precision, 14 bytes/param:
//! * per-tensor fp16 model weights (`model.<name>`, 2 B/param) — the
//!   inference-usable half, packed from the fp32 master copy;
//! * flat fp32 master copy + Adam m + v (12 B/param);
//! * training extras (step counter, data cursor) in the stream header.

use std::collections::BTreeMap;

use crate::runtime::artifacts::ModelArtifact;
use crate::tensor::{DType, Tensor, TensorStore};
use crate::util::f16::encode_f16;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Host-resident training state for one model replica.
#[derive(Debug, Clone)]
pub struct TrainState {
    /// The model's lowered-artifact description.
    pub artifact: ModelArtifact,
    /// fp32 master parameters, padded to the Pallas grid (n_padded).
    pub theta: Vec<f32>,
    /// Adam first-moment estimates.
    pub m: Vec<f32>,
    /// Adam second-moment estimates.
    pub v: Vec<f32>,
    /// Completed optimizer steps (1-based for the next step's bias
    /// correction).
    pub step: u64,
    /// Data-iterator cursor (batches consumed) — restored on resume so
    /// training continues on the exact sample stream.
    pub data_cursor: u64,
}

impl TrainState {
    /// GPT-2-style init (0.02 normals for weights, zeros/ones for
    /// biases/scales, padding zeroed), seeded and deterministic.
    pub fn init(artifact: &ModelArtifact, seed: u64) -> TrainState {
        let n = artifact.n_padded;
        let mut theta = vec![0f32; n];
        let mut rng = Rng::new(seed);
        for t in &artifact.tensors {
            let scale = if t.name.ends_with(".bias") {
                0.0
            } else if t.name.ends_with(".scale") {
                // LayerNorm scales start at one
                for slot in &mut theta[t.offset..t.offset + t.size] {
                    *slot = 1.0;
                }
                continue;
            } else if t.name.ends_with("attn.wo") || t.name.ends_with("ffn.w2") {
                0.02 / (2.0 * artifact.n_layer as f64).sqrt()
            } else {
                0.02
            };
            if scale != 0.0 {
                for slot in &mut theta[t.offset..t.offset + t.size] {
                    *slot = (rng.normal() * scale) as f32;
                }
            }
        }
        TrainState {
            artifact: artifact.clone(),
            theta,
            m: vec![0f32; n],
            v: vec![0f32; n],
            step: 0,
            data_cursor: 0,
        }
    }

    /// Padded parameter count (the Pallas grid size).
    pub fn n_padded(&self) -> usize {
        self.artifact.n_padded
    }

    /// Serialize to the checkpoint [`TensorStore`] (the §2.1.3 state).
    pub fn to_store(&self) -> TensorStore {
        let mut store = TensorStore::new();
        // fp16 model weights, one serialized tensor per logical tensor
        for t in &self.artifact.tensors {
            let slice = &self.theta[t.offset..t.offset + t.size];
            let tensor = Tensor::new(
                &format!("model.{}", t.name),
                DType::F16,
                t.shape.clone(),
                encode_f16(slice),
            )
            .expect("fp16 tensor");
            store.push(tensor).expect("unique tensor names");
        }
        // fp32 optimizer state, flat (padded — the Pallas grid shape)
        let n = self.n_padded();
        store
            .push(Tensor::from_f32("optimizer.master", vec![n], &self.theta).unwrap())
            .unwrap();
        store.push(Tensor::from_f32("optimizer.m", vec![n], &self.m).unwrap()).unwrap();
        store.push(Tensor::from_f32("optimizer.v", vec![n], &self.v).unwrap()).unwrap();
        store
    }

    /// Header extras (step counter, data cursor, model name).
    pub fn extras(&self) -> BTreeMap<String, Json> {
        let mut extra = BTreeMap::new();
        extra.insert("step".into(), Json::Int(self.step as i64));
        extra.insert("data_cursor".into(), Json::Int(self.data_cursor as i64));
        extra.insert("model".into(), Json::str(&self.artifact.name));
        extra
    }

    /// Restore from a loaded checkpoint store + header extras.
    pub fn from_store(
        artifact: &ModelArtifact,
        store: &TensorStore,
        extra: &BTreeMap<String, Json>,
    ) -> Result<TrainState> {
        let n = artifact.n_padded;
        let get_flat = |name: &str| -> Result<Vec<f32>> {
            let t = store
                .get(name)
                .ok_or_else(|| Error::Format(format!("checkpoint missing {name}")))?;
            let v = t.as_f32()?;
            if v.len() != n {
                return Err(Error::Format(format!(
                    "{name}: {} elems, model wants {n}",
                    v.len()
                )));
            }
            Ok(v)
        };
        let theta = get_flat("optimizer.master")?;
        let m = get_flat("optimizer.m")?;
        let v = get_flat("optimizer.v")?;
        let step = extra
            .get("step")
            .and_then(|j| j.as_i64().ok())
            .ok_or_else(|| Error::Format("checkpoint missing step".into()))? as u64;
        let data_cursor = extra
            .get("data_cursor")
            .and_then(|j| j.as_i64().ok())
            .unwrap_or(0) as u64;
        let name = extra.get("model").and_then(|j| j.as_str().ok().map(String::from));
        if let Some(name) = name {
            if name != artifact.name {
                return Err(Error::Config(format!(
                    "checkpoint is for model {name:?}, loading as {:?}",
                    artifact.name
                )));
            }
        }
        Ok(TrainState { artifact: artifact.clone(), theta, m, v, step, data_cursor })
    }

    /// Checkpoint-state size in bytes (≈14 B/param, §2.1.3).
    pub fn checkpoint_bytes(&self) -> u64 {
        2 * self.artifact.n_params as u64 + 12 * self.artifact.n_padded as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactManifest;
    use std::path::PathBuf;

    fn tiny() -> Option<ModelArtifact> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ArtifactManifest::load(&dir).ok().map(|m| m.config("tiny").unwrap().clone())
    }

    #[test]
    fn init_is_deterministic_and_structured() {
        let Some(art) = tiny() else { return };
        let a = TrainState::init(&art, 1);
        let b = TrainState::init(&art, 1);
        let c = TrainState::init(&art, 2);
        assert_eq!(a.theta, b.theta);
        assert_ne!(a.theta, c.theta);
        // scales are ones
        let scale_t = art.tensors.iter().find(|t| t.name.ends_with("ln1.scale")).unwrap();
        assert!(a.theta[scale_t.offset..scale_t.offset + 4].iter().all(|&x| x == 1.0));
        // padding is zero
        assert!(a.theta[art.n_params..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn store_roundtrip_restores_exactly() {
        let Some(art) = tiny() else { return };
        let mut s = TrainState::init(&art, 3);
        s.step = 41;
        s.data_cursor = 17;
        s.m[5] = 0.25;
        s.v[9] = 0.125;
        let store = s.to_store();
        let restored = TrainState::from_store(&art, &store, &s.extras()).unwrap();
        assert_eq!(restored.theta, s.theta);
        assert_eq!(restored.m, s.m);
        assert_eq!(restored.v, s.v);
        assert_eq!(restored.step, 41);
        assert_eq!(restored.data_cursor, 17);
    }

    #[test]
    fn checkpoint_is_14_bytes_per_param() {
        let Some(art) = tiny() else { return };
        let s = TrainState::init(&art, 0);
        assert_eq!(s.to_store().total_bytes(), s.checkpoint_bytes());
    }

    #[test]
    fn wrong_model_rejected() {
        let Some(art) = tiny() else { return };
        let s = TrainState::init(&art, 0);
        let store = s.to_store();
        let mut extras = s.extras();
        extras.insert("model".into(), Json::str("other-model"));
        assert!(TrainState::from_store(&art, &store, &extras).is_err());
    }
}
