//! Real training: PJRT-executed GPT training loop with FastPersist
//! checkpointing (the end-to-end proof that all layers compose).

pub mod data;
pub mod looper;
pub mod state;

pub use data::SyntheticCorpus;
pub use looper::{CkptRunMode, Trainer, TrainerConfig};
pub use state::TrainState;
