//! The training loop: PJRT-executed GPT training with per-iteration
//! FastPersist checkpointing.
//!
//! Each iteration runs the AOT-compiled `grad_step` (forward+backward)
//! and `adam_step` (fused-Adam optimizer) HLOs, with the checkpoint
//! lifecycle of Fig. 3/§4.3 around them:
//!
//! ```text
//! grads, loss = grad_step(θ, batch)      // F+B — overlaps C_{i-1}
//! wait_previous()                        // O_i ← C_{i-1} dependency
//! θ,m,v = adam_step(θ, grads, m, v, i)   // O_i
//! request_checkpoint(state_i)            // C_i (helper thread)
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use crate::checkpoint::codec::CodecKind;
use crate::checkpoint::delta::{self, CheckpointStrategy, DeltaCheckpointer};
use crate::checkpoint::engine::{CheckpointEngine, CheckpointOutcome};
use crate::checkpoint::lazy::{LazyCheckpointer, LazyConfig};
use crate::checkpoint::load::{load_checkpoint_with, RestoreOptions};
use crate::checkpoint::pipeline::PipelinedCheckpointer;
use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::topology::RankPlacement;
use crate::io::device::DeviceMap;
use crate::io::engine::{EngineKind, IoConfig};
use crate::io::read::ReadStats;
use crate::io::runtime::{IoRuntime, IoRuntimeConfig};
use crate::metrics::{Recorder, Timer};
use crate::runtime::artifacts::ArtifactManifest;
use crate::runtime::client::{lit_f32, lit_i32, to_f32_scalar, to_f32_vec, Executable, Runtime};
use crate::training::data::SyntheticCorpus;
use crate::training::state::TrainState;
use crate::{Error, Result};

/// Checkpointing mode for a real training run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptRunMode {
    /// No checkpointing.
    None,
    /// torch.save-style: buffered single writer, synchronous.
    Baseline,
    /// FastPersist write path, synchronous (no pipelining).
    Sync,
    /// Full FastPersist: parallel writers + pipelined with F/B.
    Pipelined,
    /// Lazy capture/flush split: step end memcpy-captures the state
    /// into staging buffers (a *generation*); the flush drains across
    /// the following iterations. Relaxes the `O_{i+1} ← C_i`
    /// dependency — the trainer stalls only on staged backpressure
    /// (staging budget full, or `lazy_max_generations` still in
    /// flight), never on durability.
    Lazy,
}

impl CkptRunMode {
    /// Parse a CLI mode name.
    pub fn parse(s: &str) -> Result<CkptRunMode> {
        match s {
            "none" => Ok(CkptRunMode::None),
            "baseline" | "torch" => Ok(CkptRunMode::Baseline),
            "sync" => Ok(CkptRunMode::Sync),
            "pipelined" | "fastpersist" => Ok(CkptRunMode::Pipelined),
            "lazy" => Ok(CkptRunMode::Lazy),
            other => crate::config_err!("unknown checkpoint mode {other:?}"),
        }
    }
}

/// Configuration for a training run.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// Model config name (from the artifact manifest).
    pub model: String,
    /// Training iterations to run.
    pub steps: u64,
    /// Checkpoint every n iterations (0 = never; 1 = the paper's
    /// frequent-checkpointing regime).
    pub ckpt_every: u64,
    /// Directory receiving `step-NNNNNNNN` checkpoint dirs.
    pub ckpt_dir: PathBuf,
    /// How checkpoint writes relate to compute (sync/pipelined/...).
    pub mode: CkptRunMode,
    /// Which DP ranks write (rank0/replica/socket/...). Applies to
    /// full-snapshot checkpoints only: delta checkpoints are diffed and
    /// written by one logical writer (chunk jobs still fan out over the
    /// runtime's writer pool and device map), so this knob is inert
    /// under `CheckpointStrategy::Delta`.
    pub strategy: WriterStrategy,
    /// Full snapshots every checkpoint, or chunk-granular deltas
    /// (incremental checkpointing — [`crate::checkpoint::delta`]).
    /// Delta applies to `Sync` and `Pipelined` modes; `Baseline` is the
    /// torch.save stand-in and stays full-snapshot.
    pub ckpt_strategy: CheckpointStrategy,
    /// Target payload bytes per delta segment file (`--segment-bytes`;
    /// see [`crate::checkpoint::delta::DeltaConfig::segment_bytes`]).
    /// Applied to the delta writer whatever `ckpt_strategy` spelled out;
    /// must be at least the 4 KiB alignment unit.
    pub segment_bytes: u64,
    /// Per-chunk codec applied between serialization and segment
    /// packing (`--ckpt-codec`; see [`crate::checkpoint::codec`]).
    /// Under `CheckpointStrategy::Full` a non-`None` codec routes the
    /// write through the codec-capable delta writer with `max_chain = 0`
    /// (every checkpoint a fresh base) — the partitioned full engine
    /// stays codec-oblivious, and the `strategy` knob is then inert as
    /// under delta. `Baseline` rejects any codec: it is the torch.save
    /// stand-in and must write plain bytes.
    pub ckpt_codec: CodecKind,
    /// Write-path tuning (engine kind, staging size, durability).
    pub io: IoConfig,
    /// Storage mount points to stripe checkpoint partitions across
    /// (empty map = everything in `ckpt_dir`).
    pub devices: DeviceMap,
    /// Simulated DP writer ranks (threads) for parallel writes.
    pub dp_writers: usize,
    /// Gradient-accumulation steps per optimizer update (§2.1.2): F+B
    /// runs `grad_accum` times per iteration, grads are averaged, and
    /// one Adam step is applied.
    pub grad_accum: u64,
    /// Init + data seed.
    pub seed: u64,
    /// Keep only the most recent k checkpoints (0 = keep all).
    pub keep_last: usize,
    /// Lazy-mode staging budget in bytes (`--ckpt-staging`): the cap on
    /// captured-but-not-yet-durable checkpoint bytes. Capture blocks
    /// (measured as backpressure stall) when the budget is exhausted.
    /// Ignored outside [`CkptRunMode::Lazy`].
    pub lazy_staging_bytes: u64,
    /// Lazy-mode bound on generations captured but not yet durable
    /// (`--ckpt-gens`). 1 restores eager semantics (capture waits for
    /// the previous flush); larger values deepen the flush pipeline at
    /// the cost of a longer durability lag on crash. Ignored outside
    /// [`CkptRunMode::Lazy`].
    pub lazy_max_generations: usize,
    /// Segment-GC occupancy threshold (see
    /// [`crate::checkpoint::delta::GcPolicy`]): demoted chunk stores
    /// whose live-byte occupancy falls below this are sparsely
    /// rewritten during pruning. 0.0 never rewrites; 1.0 rewrites on
    /// any dead chunk.
    pub gc_occupancy: f64,
    /// Serve-layer segment cache budget for resume restores
    /// (`--serve-cache-bytes`): when nonzero, [`Trainer::resume`]
    /// restores through a [`crate::checkpoint::serve::RestoreService`]
    /// whose segment cache holds up to this many bytes, and the cache
    /// hit/miss counters land in the `ckpt_cache_*` recorder metrics.
    /// 0 restores directly through the loader (no cache).
    pub serve_cache_bytes: u64,
    /// Print a progress line every n steps (0 = silent).
    pub log_every: u64,
}

impl TrainerConfig {
    /// Small defaults for tests/examples: 10 steps, per-iteration
    /// pipelined full checkpoints.
    pub fn quick(model: &str, dir: PathBuf) -> TrainerConfig {
        TrainerConfig {
            model: model.to_string(),
            steps: 10,
            ckpt_every: 1,
            ckpt_dir: dir,
            mode: CkptRunMode::Pipelined,
            strategy: WriterStrategy::AllReplicas,
            ckpt_strategy: CheckpointStrategy::Full,
            segment_bytes: delta::DeltaConfig::default().segment_bytes,
            ckpt_codec: CodecKind::None,
            io: IoConfig::fastpersist(),
            devices: DeviceMap::single(),
            dp_writers: 2,
            grad_accum: 1,
            seed: 0,
            keep_last: 2,
            lazy_staging_bytes: LazyConfig::default().staging_bytes,
            lazy_max_generations: LazyConfig::default().max_generations,
            gc_occupancy: delta::GcPolicy::default().occupancy,
            serve_cache_bytes: 0,
            log_every: 0,
        }
    }
}

/// Read-path accounting of the restore a resumed trainer booted from —
/// the symmetric counterpart of the per-checkpoint write-job/fsync
/// metrics.
#[derive(Debug, Clone)]
pub struct RestoreReport {
    /// Merged counters from every read job of the restore.
    pub stats: ReadStats,
    /// Wall latency of the whole restore (read + verify + parse).
    pub latency: std::time::Duration,
    /// Stream bytes the restore assembled.
    pub total_bytes: u64,
    /// Submission backend recorded in the restored checkpoint's
    /// manifest (`"sync"` / `"ring"`; `None` on pre-field manifests) —
    /// restore logs report which path produced the checkpoint.
    pub io_backend: Option<String>,
}

impl RestoreReport {
    /// Restore throughput in decimal GB/s.
    pub fn gbps(&self) -> f64 {
        crate::util::bytes::gbps(self.total_bytes, self.latency.as_secs_f64())
    }
}

/// One completed checkpoint's recorder-bound counters, copied out of a
/// helper-owned outcome list before the borrow on the checkpointer is
/// released (the recorder needs `&mut self`).
struct HarvestedCkpt {
    latency: f64,
    bytes: u64,
    jobs: u64,
    fsyncs: u64,
    direct_extents: u64,
    bounce: u64,
    ring: [u64; 3],
    bytes_raw: u64,
    bytes_encoded: u64,
    encode_s: f64,
}

impl HarvestedCkpt {
    fn of(o: &CheckpointOutcome) -> HarvestedCkpt {
        HarvestedCkpt {
            latency: o.latency.as_secs_f64(),
            bytes: o.written_bytes,
            jobs: o.stats.len() as u64,
            fsyncs: o.stats.iter().map(|s| s.fsyncs).sum::<u64>(),
            direct_extents: o.direct_extents(),
            bounce: o.bounce_bytes(),
            ring: [o.batched_submissions(), o.sqes_per_submit_max(), o.completions_reaped()],
            bytes_raw: o.bytes_raw,
            bytes_encoded: o.bytes_encoded,
            encode_s: o.encode.as_secs_f64(),
        }
    }
}

/// The training driver.
pub struct Trainer {
    /// The run's configuration.
    pub cfg: TrainerConfig,
    /// Live training state (parameters, moments, step).
    pub state: TrainState,
    /// Per-iteration metrics (loss, timings, counters).
    pub recorder: Recorder,
    /// Read-path accounting of the checkpoint restore this trainer was
    /// resumed from (`None` for fresh runs).
    pub restore: Option<RestoreReport>,
    grad_exe: Executable,
    adam_exe: Executable,
    corpus: SyntheticCorpus,
    group: Vec<RankPlacement>,
    /// The long-lived I/O subsystem: staging buffers, writer/drain
    /// threads, device map — shared by every checkpoint of this run.
    io_runtime: Arc<IoRuntime>,
    /// Synchronous-mode engine (Baseline/Sync), built once at setup —
    /// engine construction is off the per-iteration hot path.
    engine: Option<CheckpointEngine>,
    /// Synchronous delta writer (Sync mode + Delta strategy); in
    /// Pipelined mode the delta writer lives on the helper thread.
    delta: Option<DeltaCheckpointer>,
    pipe: Option<PipelinedCheckpointer>,
    /// Pipelined outcomes already harvested into the recorder.
    pipe_seen: usize,
    /// Lazy capture/flush checkpointer (Lazy mode; full or delta
    /// flavor per `ckpt_strategy`).
    lazy: Option<LazyCheckpointer>,
    /// Lazy outcomes already harvested into the recorder.
    lazy_seen: usize,
}

impl Trainer {
    /// Build a trainer, initializing fresh state.
    pub fn new(manifest: &ArtifactManifest, cfg: TrainerConfig) -> Result<Trainer> {
        let artifact = manifest.config(&cfg.model)?.clone();
        let state = TrainState::init(&artifact, cfg.seed);
        Self::with_state(manifest, cfg, state, None, false)
    }

    /// Build a trainer (fresh state) submitting checkpoints into an
    /// existing shared [`IoRuntime`] instead of constructing a private
    /// one — several trainers (or trainers + direct writes) can then
    /// share one staging pool, writer pool, and device map.
    pub fn new_with_runtime(
        manifest: &ArtifactManifest,
        cfg: TrainerConfig,
        runtime: Arc<IoRuntime>,
    ) -> Result<Trainer> {
        let artifact = manifest.config(&cfg.model)?.clone();
        let state = TrainState::init(&artifact, cfg.seed);
        Self::with_state(manifest, cfg, state, Some(runtime), false)
    }

    /// Build a trainer resuming from the latest checkpoint in
    /// `cfg.ckpt_dir` (error if none found). The restore goes through
    /// the same shared [`IoRuntime`] the trainer will checkpoint with —
    /// its reader pool, device map and stream-buffer accounting — and
    /// the read-path counters land in [`Trainer::restore`] plus the
    /// `ckpt_read_*` recorder metrics.
    pub fn resume(manifest: &ArtifactManifest, cfg: TrainerConfig) -> Result<Trainer> {
        let runtime = Self::runtime_for(&cfg);
        Self::resume_with_runtime(manifest, cfg, runtime)
    }

    /// Like [`Trainer::resume`], restoring through (and then submitting
    /// checkpoints into) an injected shared runtime.
    pub fn resume_with_runtime(
        manifest: &ArtifactManifest,
        cfg: TrainerConfig,
        runtime: Arc<IoRuntime>,
    ) -> Result<Trainer> {
        let artifact = manifest.config(&cfg.model)?.clone();
        let latest = Self::latest_checkpoint(&cfg.ckpt_dir)?
            .ok_or_else(|| Error::Config(format!(
                "no checkpoint found under {}",
                cfg.ckpt_dir.display()
            )))?;
        let mut cache_stats = None;
        let loaded = if cfg.serve_cache_bytes > 0 {
            let service = crate::checkpoint::serve::RestoreService::new(
                Arc::clone(&runtime),
                crate::checkpoint::serve::ServeConfig::with_cache(cfg.serve_cache_bytes),
            );
            let loaded = service.session("trainer-resume").restore(&latest)?;
            cache_stats = Some(service.cache_stats());
            loaded
        } else {
            load_checkpoint_with(&latest, &runtime, RestoreOptions::default())?
        };
        let state = TrainState::from_store(&artifact, &loaded.store, &loaded.header.extra)?;
        let mut trainer = Self::with_state(manifest, cfg, state, Some(runtime), true)?;
        let report = RestoreReport {
            total_bytes: loaded.manifest.total_len,
            latency: loaded.latency,
            stats: loaded.stats,
            io_backend: loaded.manifest.io_backend.clone(),
        };
        trainer.recorder.record("ckpt_read_bytes", report.stats.bytes as f64);
        trainer.recorder.record("ckpt_read_jobs", report.stats.jobs as f64);
        trainer.recorder.record("ckpt_read_preads", report.stats.preads as f64);
        trainer.recorder.record("ckpt_read_coalesced", report.stats.coalesced as f64);
        trainer.recorder.record("ckpt_read_bytes_encoded", report.stats.bytes_encoded as f64);
        trainer.recorder.record("ckpt_read_chunks_decoded", report.stats.chunks_decoded as f64);
        trainer.recorder.record("ckpt_decode_s", report.stats.decode.as_secs_f64());
        trainer.recorder.record("ckpt_restore_s", report.latency.as_secs_f64());
        if let Some(cs) = cache_stats {
            trainer.recorder.record("ckpt_cache_hits", cs.hits as f64);
            trainer.recorder.record("ckpt_cache_misses", cs.misses as f64);
        }
        trainer.restore = Some(report);
        Ok(trainer)
    }

    /// The persistent runtime a config implies: the trainer's staging
    /// pool, writer/reader pools, and device map (shared by every
    /// checkpoint write *and* the resume-time restore).
    fn runtime_for(cfg: &TrainerConfig) -> Arc<IoRuntime> {
        let defaults = IoRuntimeConfig::default();
        Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: cfg.io.clone(),
            devices: cfg.devices.clone(),
            // "N writers" must mean N concurrent partition writes (and
            // symmetric parallel restore reads): size both persistent
            // pools to the DP writer count.
            writer_threads: cfg.dp_writers.max(defaults.writer_threads),
            reader_threads: cfg.dp_writers.max(defaults.reader_threads),
            ..defaults
        }))
    }

    fn with_state(
        manifest: &ArtifactManifest,
        cfg: TrainerConfig,
        state: TrainState,
        shared_runtime: Option<Arc<IoRuntime>>,
        resumed: bool,
    ) -> Result<Trainer> {
        let artifact = &state.artifact;
        let rt = Runtime::cpu()?;
        let grad_exe = rt.load_entry(manifest, &artifact.entrypoints["grad_step"])?;
        let adam_exe = rt.load_entry(manifest, &artifact.entrypoints["adam_step"])?;
        let corpus =
            SyntheticCorpus::new(artifact.vocab, artifact.seq, artifact.batch, cfg.seed ^ 0xda7a);
        // Simulated single-node DP group: dp_writers ranks on node 0.
        let group: Vec<RankPlacement> = (0..cfg.dp_writers.max(1))
            .map(|r| RankPlacement { rank: r, node: 0, socket: r % 2, local_gpu: r })
            .collect();
        // One persistent I/O runtime for the whole run: every checkpoint
        // (sync or pipelined) borrows its staging buffers and writer
        // threads, every restore its reader threads, and its device map
        // routes the partitions. A caller may inject an already-shared
        // runtime instead.
        let io_runtime = match shared_runtime {
            Some(rt) => rt,
            None => Self::runtime_for(&cfg),
        };
        if cfg.segment_bytes < 4096 {
            return Err(Error::Config(format!(
                "segment-bytes must be at least the 4 KiB alignment unit, got {}",
                cfg.segment_bytes
            )));
        }
        let ckpt_on = cfg.ckpt_every > 0;
        let delta_cfg = match cfg.ckpt_strategy {
            // Full snapshots with a codec route through the delta writer
            // at max_chain = 0: every checkpoint is a fresh base (no
            // diffing, no chain) but the encode stage applies. QuantDelta
            // has no prior image to diff against on a base, so it
            // degrades to storing raw bytes here; lz4 compresses as
            // usual.
            CheckpointStrategy::Full if cfg.ckpt_codec != CodecKind::None => {
                Some(delta::DeltaConfig { max_chain: 0, ..delta::DeltaConfig::default() })
            }
            CheckpointStrategy::Full => None,
            CheckpointStrategy::Delta(d) => Some(d),
        };
        // A *resumed* delta writer re-attaches its chain to the newest
        // on-disk manifest (the checkpoint the state was loaded from).
        // Fresh runs always start a base — attaching would make the new
        // run's checkpoints reference whatever stale chain happens to
        // live in a reused directory.
        let make_delta = |d: delta::DeltaConfig| -> Result<DeltaCheckpointer> {
            // thread the CLI/TrainerConfig segment-size knob into the
            // delta writer's segment packing
            let d = delta::DeltaConfig {
                segment_bytes: cfg.segment_bytes,
                codec: cfg.ckpt_codec,
                ..d
            };
            let mut dk = DeltaCheckpointer::new(Arc::clone(&io_runtime), d);
            if resumed {
                if let Some(latest) = Self::latest_checkpoint(&cfg.ckpt_dir)? {
                    let _ = dk.resume_from(&latest);
                }
            }
            Ok(dk)
        };
        let mut engine = None;
        let mut delta = None;
        let mut pipe = None;
        let mut lazy = None;
        match cfg.mode {
            CkptRunMode::None => {}
            CkptRunMode::Baseline if ckpt_on => {
                if cfg.ckpt_codec != CodecKind::None {
                    return Err(Error::Config(
                        "baseline mode is the torch.save stand-in and writes plain \
                         full snapshots; --ckpt-codec needs mode sync, pipelined, or lazy"
                            .into(),
                    ));
                }
                if delta_cfg.is_some() {
                    return Err(Error::Config(
                        "baseline mode is the full-snapshot torch.save stand-in; \
                         delta checkpointing needs mode sync or pipelined"
                            .into(),
                    ));
                }
                // torch.save-equivalent: buffered single writer, through
                // the same shared runtime.
                engine = Some(
                    CheckpointEngine::with_runtime(Arc::clone(&io_runtime), WriterStrategy::Rank0)
                        .with_kind(EngineKind::Buffered),
                );
            }
            CkptRunMode::Sync if ckpt_on => match delta_cfg {
                Some(d) => delta = Some(make_delta(d)?),
                None => {
                    engine =
                        Some(CheckpointEngine::with_runtime(Arc::clone(&io_runtime), cfg.strategy));
                }
            },
            CkptRunMode::Pipelined if ckpt_on => match delta_cfg {
                Some(d) => pipe = Some(PipelinedCheckpointer::delta(make_delta(d)?)),
                None => {
                    let e = CheckpointEngine::with_runtime(Arc::clone(&io_runtime), cfg.strategy);
                    pipe = Some(PipelinedCheckpointer::new(e, group.clone()));
                }
            },
            CkptRunMode::Lazy if ckpt_on => {
                // The capture pool's buffer size follows the I/O staging
                // buffer size, so one generation occupies a predictable
                // number of buffers.
                let lcfg = LazyConfig {
                    staging_bytes: cfg.lazy_staging_bytes,
                    buf_size: cfg.io.io_buf_size,
                    max_generations: cfg.lazy_max_generations,
                };
                lazy = Some(match delta_cfg {
                    Some(d) => LazyCheckpointer::delta(make_delta(d)?, lcfg),
                    None => {
                        let e =
                            CheckpointEngine::with_runtime(Arc::clone(&io_runtime), cfg.strategy);
                        LazyCheckpointer::full(e, group.clone(), lcfg)
                    }
                });
            }
            _ => {}
        }
        Ok(Trainer {
            cfg,
            state,
            recorder: Recorder::new(),
            restore: None,
            grad_exe,
            adam_exe,
            corpus,
            group,
            io_runtime,
            engine,
            delta,
            pipe,
            pipe_seen: 0,
            lazy,
            lazy_seen: 0,
        })
    }

    /// Record latency + written-bytes + write-job/fsync metrics for
    /// pipelined checkpoints that completed since the last harvest.
    /// `written_bytes` is the outcome's payload accounting (for deltas,
    /// dirty chunks only — the same quantity Sync mode records, so the
    /// metric is comparable across modes), while job/fsync counts come
    /// from the per-partition/per-segment [`crate::io::WriteStats`].
    fn harvest_pipe_outcomes(&mut self) {
        let harvested: Vec<HarvestedCkpt> = match self.pipe.as_ref() {
            Some(pipe) => pipe.completed[self.pipe_seen..].iter().map(HarvestedCkpt::of).collect(),
            None => return,
        };
        self.pipe_seen += harvested.len();
        for h in harvested {
            self.record_ckpt_outcome(h);
        }
    }

    /// Record metrics for lazy generations that became durable since the
    /// last harvest: the same latency/bytes/job/fsync series the other
    /// modes record (comparable across modes), plus `drain_s` — the
    /// helper-side flush time per generation, the concurrent-work
    /// counterpart of the trainer-side `stall_s`.
    fn harvest_lazy_outcomes(&mut self) {
        let harvested: Vec<(f64, HarvestedCkpt)> = match self.lazy.as_ref() {
            Some(lz) => lz.completed[self.lazy_seen..]
                .iter()
                .map(|o| (o.drain.as_secs_f64(), HarvestedCkpt::of(&o.outcome)))
                .collect(),
            None => return,
        };
        self.lazy_seen += harvested.len();
        for (drain, h) in harvested {
            self.recorder.record("drain_s", drain);
            self.record_ckpt_outcome(h);
        }
    }

    /// Record one completed checkpoint's shared metric series — the same
    /// names whatever mode produced it, so the series stay comparable
    /// across modes. The codec counters (`ckpt_bytes_raw` /
    /// `ckpt_bytes_encoded` / `ckpt_encode_s`) land here too:
    /// `bytes_encoded / bytes_raw` is the achieved codec ratio, 1.0 when
    /// no codec is active.
    fn record_ckpt_outcome(&mut self, h: HarvestedCkpt) {
        self.recorder.record("ckpt_latency_s", h.latency);
        self.recorder.record("ckpt_written_bytes", h.bytes as f64);
        self.recorder.record("ckpt_write_jobs", h.jobs as f64);
        self.recorder.record("ckpt_fsyncs", h.fsyncs as f64);
        self.recorder.record("ckpt_direct_extents", h.direct_extents as f64);
        self.recorder.record("ckpt_bounce_bytes", h.bounce as f64);
        self.recorder.record("ckpt_bytes_raw", h.bytes_raw as f64);
        self.recorder.record("ckpt_bytes_encoded", h.bytes_encoded as f64);
        self.recorder.record("ckpt_encode_s", h.encode_s);
        self.record_ring_counters(h.ring);
    }

    /// Record one checkpoint's submission-backend counters:
    /// `[batched_submissions, sqes_per_submit_max, completions_reaped]`.
    /// All three stay zero end to end on the sync backend, which is the
    /// CLI summary's (and the bench rows') proof of which path ran.
    fn record_ring_counters(&mut self, ring: [u64; 3]) {
        self.recorder.record("ckpt_batched_submissions", ring[0] as f64);
        self.recorder.record("ckpt_sqes_per_submit_max", ring[1] as f64);
        self.recorder.record("ckpt_completions_reaped", ring[2] as f64);
    }

    /// The run's persistent I/O runtime (staging-pool counters, device
    /// map — useful for instrumentation and tests).
    pub fn io_runtime(&self) -> &Arc<IoRuntime> {
        &self.io_runtime
    }

    /// Newest checkpoint directory (by step number) under `dir`.
    pub fn latest_checkpoint(dir: &std::path::Path) -> Result<Option<PathBuf>> {
        if !dir.exists() {
            return Ok(None);
        }
        let mut best: Option<(u64, PathBuf)> = None;
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if let Some(step) = name.strip_prefix("step-").and_then(|s| s.parse::<u64>().ok()) {
                if path.join(crate::checkpoint::manifest::MANIFEST_FILE).exists()
                    && best.as_ref().map_or(true, |(b, _)| step > *b)
                {
                    best = Some((step, path));
                }
            }
        }
        Ok(best.map(|(_, p)| p))
    }

    fn step_dir(&self, step: u64) -> PathBuf {
        self.cfg.ckpt_dir.join(format!("step-{step:08}"))
    }

    /// Run `cfg.steps` training iterations; returns final mean loss of
    /// the last 10 steps.
    pub fn run(&mut self) -> Result<f64> {
        for _ in 0..self.cfg.steps {
            self.train_one_step()?;
            let step = self.state.step;
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                let losses = self.recorder.samples("loss");
                let recent = &losses[losses.len().saturating_sub(self.cfg.log_every as usize)..];
                let mean: f64 = recent.iter().sum::<f64>() / recent.len() as f64;
                println!(
                    "step {:>6}  loss {:.4}  iter {:>8.1} ms  stall {:>6.2} ms",
                    step,
                    mean,
                    self.recorder.summary("iter_s").p50 * 1e3,
                    self.recorder.summary("stall_s").mean * 1e3,
                );
            }
        }
        // drain the last in-flight checkpoint
        if let Some(pipe) = self.pipe.as_mut() {
            pipe.wait_previous()?;
        }
        self.harvest_pipe_outcomes();
        if let Some(lz) = self.lazy.as_mut() {
            lz.wait_all()?;
        }
        self.harvest_lazy_outcomes();
        let losses = self.recorder.samples("loss");
        let tail = &losses[losses.len().saturating_sub(10)..];
        Ok(tail.iter().sum::<f64>() / tail.len().max(1) as f64)
    }

    /// One training iteration with the Fig. 3 checkpoint lifecycle.
    pub fn train_one_step(&mut self) -> Result<f32> {
        let iter_timer = Timer::start();

        // F+B (× grad_accum micro-batches, §2.1.2) — overlaps any
        // in-flight checkpoint write (C_{i-1}).
        let (b, t1) = self.corpus.shape();
        let ga = self.cfg.grad_accum.max(1);
        let fb_timer = Timer::start();
        let mut grads: Vec<f32> = Vec::new();
        let mut loss = 0f32;
        for micro in 0..ga {
            let batch = self.corpus.batch_at(self.state.data_cursor + micro);
            let out = self.grad_exe.run(&[
                lit_f32(&self.state.theta),
                lit_i32(&batch, &[b as i64, t1 as i64])?,
            ])?;
            let g = to_f32_vec(&out[0])?;
            loss += to_f32_scalar(&out[1])?;
            if grads.is_empty() {
                grads = g;
            } else {
                for (a, x) in grads.iter_mut().zip(&g) {
                    *a += x;
                }
            }
        }
        if ga > 1 {
            let inv = 1.0 / ga as f32;
            for g in &mut grads {
                *g *= inv;
            }
        }
        let loss = loss / ga as f32;
        self.recorder.record("fb_s", fb_timer.secs());

        // Synchronization point: O_i must not run before C_{i-1} is
        // durable (§4.3).
        if let Some(pipe) = self.pipe.as_mut() {
            let stall = Timer::start();
            pipe.wait_previous()?;
            self.recorder.record("stall_s", stall.secs());
            self.harvest_pipe_outcomes();
        }

        // Lazy mode deliberately relaxes that dependency: durable
        // generations are harvested without blocking; the only stall is
        // capture-time backpressure, measured where it happens.
        if let Some(lz) = self.lazy.as_mut() {
            lz.poll_completed()?;
        }
        self.harvest_lazy_outcomes();

        // O_i: fused Adam via the Pallas-lowered HLO.
        let opt_timer = Timer::start();
        let next_step = self.state.step + 1;
        let out = self.adam_exe.run(&[
            lit_f32(&self.state.theta),
            lit_f32(&grads),
            lit_f32(&self.state.m),
            lit_f32(&self.state.v),
            lit_f32(&[next_step as f32]),
        ])?;
        self.state.theta = to_f32_vec(&out[0])?;
        self.state.m = to_f32_vec(&out[1])?;
        self.state.v = to_f32_vec(&out[2])?;
        self.state.step = next_step;
        self.state.data_cursor += ga;
        self.recorder.record("opt_s", opt_timer.secs());
        self.recorder.record("loss", loss as f64);

        // C_i: checkpoint per mode.
        if self.cfg.ckpt_every > 0 && next_step % self.cfg.ckpt_every == 0 {
            let dir = self.step_dir(next_step);
            let store = self.state.to_store();
            let extras = self.state.extras();
            match self.cfg.mode {
                CkptRunMode::None => {}
                // Sync + delta: only dirty chunks go to storage.
                CkptRunMode::Sync if self.delta.is_some() => {
                    let ck = Timer::start();
                    let delta = self.delta.as_mut().expect("delta mode has writer");
                    let out = delta.write(&store, extras, &dir)?;
                    self.recorder.record("stall_s", ck.secs());
                    self.recorder.record("ckpt_latency_s", out.latency.as_secs_f64());
                    self.recorder.record("ckpt_written_bytes", out.written_bytes as f64);
                    self.recorder.record("ckpt_write_jobs", out.segments_written as f64);
                    self.recorder.record("ckpt_fsyncs", out.fsyncs as f64);
                    self.recorder.record("ckpt_direct_extents", out.direct_extents() as f64);
                    self.recorder.record("ckpt_bounce_bytes", out.bounce_bytes() as f64);
                    self.recorder.record("ckpt_bytes_raw", out.bytes_raw as f64);
                    self.recorder.record("ckpt_bytes_encoded", out.bytes_encoded as f64);
                    self.recorder.record("ckpt_encode_s", out.encode.as_secs_f64());
                    self.record_ring_counters([
                        out.batched_submissions(),
                        out.sqes_per_submit_max(),
                        out.completions_reaped(),
                    ]);
                    self.recorder.count("ckpts", 1);
                }
                // Baseline and Sync share the persistent engine built at
                // setup: no per-iteration engine construction, staging
                // buffers recycled from the shared runtime pool.
                CkptRunMode::Baseline | CkptRunMode::Sync => {
                    let ck = Timer::start();
                    let engine = self.engine.as_ref().expect("sync mode has engine");
                    let out = engine.write(&store, extras, &dir, &self.group)?;
                    self.recorder.record("stall_s", ck.secs());
                    let h = HarvestedCkpt::of(&out);
                    self.record_ckpt_outcome(h);
                    self.recorder.count("ckpts", 1);
                }
                CkptRunMode::Pipelined => {
                    let pipe = self.pipe.as_mut().expect("pipelined mode has helper");
                    pipe.request(&store, extras, dir)?;
                    self.recorder.count("ckpts", 1);
                }
                // Lazy: memcpy the state into staging and return; the
                // flush drains on the helper across the following
                // iterations. The trainer pays the copy plus any staged
                // backpressure — both measured, never hidden.
                CkptRunMode::Lazy => {
                    let lz = self.lazy.as_mut().expect("lazy mode has checkpointer");
                    let cs = lz.capture(&store, extras, dir)?;
                    self.recorder.record("stall_s", (cs.stall + cs.copy).as_secs_f64());
                    self.recorder.record("ckpt_capture_s", cs.copy.as_secs_f64());
                    self.recorder.record("ckpt_backpressure_s", cs.stall.as_secs_f64());
                    self.recorder.record("ckpt_captured_bytes", cs.bytes as f64);
                    self.recorder.count("ckpts", 1);
                }
            }
            self.prune_old(next_step)?;
        }

        self.recorder.record("iter_s", iter_timer.secs());
        Ok(loss)
    }

    /// Delete checkpoints older than keep_last (never the newest).
    ///
    /// Pruning is always chain-aware
    /// ([`crate::checkpoint::delta::prune_chain`]), whatever the current
    /// strategy: full manifests reference no foreign chunks and are
    /// simply removed when old, while directories whose chunks are still
    /// referenced by kept deltas — including chains left by a *previous*
    /// run with a different strategy — are demoted to chunk stores,
    /// with segment-granular GC (dead segments deleted, under-occupied
    /// ones sparsely rewritten per `cfg.gc_occupancy`). GC uses the
    /// runtime's device map (the one writes were actually routed with);
    /// `cfg.devices` may be a stale default when a shared runtime was
    /// injected.
    fn prune_old(&self, newest: u64) -> Result<()> {
        if self.cfg.keep_last == 0 {
            return Ok(());
        }
        delta::prune_chain_with(
            &self.cfg.ckpt_dir,
            self.cfg.keep_last,
            self.io_runtime.devices(),
            Some(newest),
            delta::GcPolicy { occupancy: self.cfg.gc_occupancy },
        )?;
        Ok(())
    }

    /// Collect per-mode stall totals for reporting.
    pub fn total_stall(&self) -> f64 {
        let recorded = self.recorder.total("stall_s");
        let helper = match (&self.pipe, &self.lazy) {
            (Some(p), _) => p.stall.as_secs_f64(),
            (None, Some(l)) => l.stall.as_secs_f64(),
            (None, None) => 0.0,
        };
        recorded.max(helper)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<ArtifactManifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ArtifactManifest::load(&dir).ok()
    }

    fn scratch(tag: &str) -> PathBuf {
        crate::io::engine::scratch_dir(tag).unwrap()
    }

    #[test]
    fn tiny_training_reduces_loss() {
        let Some(m) = manifest() else { return };
        let dir = scratch("train-loss");
        let mut cfg = TrainerConfig::quick("tiny", dir.clone());
        cfg.steps = 30;
        cfg.ckpt_every = 0;
        cfg.mode = CkptRunMode::None;
        let mut t = Trainer::new(&m, cfg).unwrap();
        let first = t.train_one_step().unwrap();
        for _ in 0..29 {
            t.train_one_step().unwrap();
        }
        let last = *t.recorder.samples("loss").last().unwrap();
        assert!(
            (last as f32) < first - 0.5,
            "loss did not decrease: {first} -> {last}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn per_iteration_checkpointing_produces_checkpoints() {
        let Some(m) = manifest() else { return };
        let dir = scratch("train-ckpt");
        let mut cfg = TrainerConfig::quick("tiny", dir.clone());
        cfg.steps = 5;
        cfg.keep_last = 0; // keep all
        let mut t = Trainer::new(&m, cfg).unwrap();
        t.run().unwrap();
        for step in 1..=5u64 {
            let d = dir.join(format!("step-{step:08}"));
            assert!(d.join("checkpoint.json").exists(), "missing {d:?}");
        }
        assert_eq!(t.recorder.counter("ckpts"), 5);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_restores_exact_state_and_stream() {
        let Some(m) = manifest() else { return };
        let dir = scratch("train-resume");
        // train 6 steps with checkpoints
        let mut cfg = TrainerConfig::quick("tiny", dir.clone());
        cfg.steps = 6;
        cfg.keep_last = 0;
        let mut t1 = Trainer::new(&m, cfg.clone()).unwrap();
        t1.run().unwrap();
        let theta_after6 = t1.state.theta.clone();
        // keep training to 8 for the reference trajectory (no further
        // checkpoints, so step-6 stays the latest on disk)
        t1.cfg.steps = 2;
        t1.cfg.ckpt_every = 0;
        let mut t_ref = t1;
        t_ref.run().unwrap();

        // resume from the step-6 checkpoint and train the same 2 steps
        let mut t2 = Trainer::resume(&m, cfg).unwrap();
        assert_eq!(t2.state.step, 6);
        assert_eq!(t2.state.theta, theta_after6);
        t2.cfg.steps = 2;
        t2.run().unwrap();
        assert_eq!(t2.state.step, t_ref.state.step);
        assert_eq!(t2.state.theta, t_ref.state.theta, "resumed trajectory diverged");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn modes_produce_identical_checkpoint_content() {
        let Some(m) = manifest() else { return };
        let base_dir = scratch("train-modes");
        let mut stores = Vec::new();
        for (tag, mode) in [
            ("b", CkptRunMode::Baseline),
            ("s", CkptRunMode::Sync),
            ("p", CkptRunMode::Pipelined),
            ("l", CkptRunMode::Lazy),
        ] {
            let dir = base_dir.join(tag);
            let mut cfg = TrainerConfig::quick("tiny", dir.clone());
            cfg.steps = 3;
            cfg.mode = mode;
            let mut t = Trainer::new(&m, cfg).unwrap();
            t.run().unwrap();
            let latest = Trainer::latest_checkpoint(&dir).unwrap().unwrap();
            let (store, header, _) =
                crate::checkpoint::load::load_checkpoint(&latest, t.io_runtime()).unwrap();
            assert_eq!(header.extra["step"], crate::util::json::Json::Int(3));
            stores.push(store);
        }
        assert!(stores[0].content_eq(&stores[1]), "baseline vs sync differ");
        assert!(stores[1].content_eq(&stores[2]), "sync vs pipelined differ");
        assert!(stores[2].content_eq(&stores[3]), "pipelined vs lazy differ");
        std::fs::remove_dir_all(&base_dir).unwrap();
    }

    #[test]
    fn lazy_delta_mode_checkpoints_chain_and_resumes_exactly() {
        use crate::checkpoint::delta::{CheckpointStrategy, DeltaConfig};
        let Some(m) = manifest() else { return };
        let dir = scratch("train-lazy-delta");
        let mut cfg = TrainerConfig::quick("tiny", dir.clone());
        cfg.steps = 5;
        cfg.keep_last = 0;
        cfg.mode = CkptRunMode::Lazy;
        cfg.ckpt_strategy = CheckpointStrategy::Delta(DeltaConfig {
            chunk_size: 4096,
            max_chain: 8,
            ..DeltaConfig::default()
        });
        let mut t = Trainer::new(&m, cfg.clone()).unwrap();
        t.run().unwrap();
        let theta_after5 = t.state.theta.clone();
        // run() drained every generation: all five checkpoints durable,
        // steps 2.. are deltas in one chain
        for step in 1..=5u64 {
            let d = dir.join(format!("step-{step:08}"));
            let mf = crate::checkpoint::manifest::CheckpointManifest::load(&d).unwrap();
            assert!(mf.is_delta(), "step {step}");
            assert_eq!(mf.delta.as_ref().unwrap().chain_len, step - 1);
        }
        // the overlap accounting is split: trainer-side stall (capture +
        // backpressure) and helper-side drain are separate series, one
        // drain sample per durable generation
        assert_eq!(t.recorder.samples("drain_s").len(), 5);
        assert_eq!(t.recorder.samples("ckpt_capture_s").len(), 5);
        assert_eq!(t.recorder.samples("ckpt_backpressure_s").len(), 5);
        assert!(t.recorder.total("drain_s") > 0.0);
        // a lazy-written chain resumes bit-identically
        let t2 = Trainer::resume(&m, cfg).unwrap();
        assert_eq!(t2.state.step, 5);
        assert_eq!(t2.state.theta, theta_after5);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn delta_mode_trains_checkpoints_and_resumes_exactly() {
        use crate::checkpoint::delta::{CheckpointStrategy, DeltaConfig};
        let Some(m) = manifest() else { return };
        let dir = scratch("train-delta");
        let mut cfg = TrainerConfig::quick("tiny", dir.clone());
        cfg.steps = 5;
        cfg.keep_last = 0;
        cfg.mode = CkptRunMode::Sync;
        cfg.ckpt_strategy = CheckpointStrategy::Delta(DeltaConfig {
            chunk_size: 4096,
            max_chain: 8,
            ..DeltaConfig::default()
        });
        let mut t = Trainer::new(&m, cfg.clone()).unwrap();
        t.run().unwrap();
        let theta_after5 = t.state.theta.clone();
        // all five checkpoints exist, steps 2.. are deltas
        for step in 1..=5u64 {
            let d = dir.join(format!("step-{step:08}"));
            let mf = crate::checkpoint::manifest::CheckpointManifest::load(&d).unwrap();
            assert!(mf.is_delta(), "step {step}");
            assert_eq!(mf.delta.as_ref().unwrap().chain_len, step - 1);
        }
        // segment coalescing is visible in the metrics: each delta
        // checkpoint issued a bounded number of WriteJobs (segments) and
        // one fsync per job under the durable default config — never
        // one per chunk
        let jobs = t.recorder.samples("ckpt_write_jobs").to_vec();
        assert_eq!(jobs.len(), 5);
        assert!(jobs.iter().all(|&j| (1.0..=2.0).contains(&j)), "jobs = {jobs:?}");
        let fsyncs = t.recorder.samples("ckpt_fsyncs").to_vec();
        assert_eq!(fsyncs.len(), 5);
        assert!(
            fsyncs.iter().zip(&jobs).all(|(f, j)| f == j),
            "durable delta writes fsync once per segment"
        );
        // a delta-chain resume restores bit-identical state, and its
        // read-path accounting is surfaced symmetrically with the
        // write-job/fsync metrics
        let t2 = Trainer::resume(&m, cfg).unwrap();
        assert_eq!(t2.state.step, 5);
        assert_eq!(t2.state.theta, theta_after5);
        let report = t2.restore.as_ref().expect("resume must report its restore");
        assert!(report.stats.jobs > 0);
        assert_eq!(report.stats.bytes, report.total_bytes);
        assert!(report.stats.coalesced > 0, "chain restore must coalesce adjacent chunks");
        assert_eq!(t2.recorder.samples("ckpt_read_jobs").len(), 1);
        assert_eq!(
            t2.recorder.total("ckpt_read_coalesced"),
            report.stats.coalesced as f64
        );
        // the restore went through the trainer's own shared runtime:
        // exactly one stream allocation of the manifest's total_len
        assert_eq!(
            t2.io_runtime().stream_allocations(),
            (1, report.total_bytes),
            "one restore = one stream buffer of total_len"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn segment_bytes_below_alignment_is_rejected() {
        let Some(m) = manifest() else { return };
        let dir = scratch("train-segbytes");
        let mut cfg = TrainerConfig::quick("tiny", dir.clone());
        cfg.segment_bytes = 1024; // below the 4 KiB alignment unit
        match Trainer::new(&m, cfg) {
            Err(crate::Error::Config(msg)) => {
                assert!(msg.contains("4 KiB"), "clear alignment error expected: {msg}")
            }
            other => panic!("expected config error, got {:?}", other.map(|_| ())),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn baseline_mode_rejects_delta_strategy() {
        use crate::checkpoint::delta::{CheckpointStrategy, DeltaConfig};
        let Some(m) = manifest() else { return };
        let dir = scratch("train-delta-baseline");
        let mut cfg = TrainerConfig::quick("tiny", dir.clone());
        cfg.mode = CkptRunMode::Baseline;
        cfg.ckpt_strategy = CheckpointStrategy::Delta(DeltaConfig::default());
        assert!(Trainer::new(&m, cfg).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keep_last_prunes() {
        let Some(m) = manifest() else { return };
        let dir = scratch("train-prune");
        let mut cfg = TrainerConfig::quick("tiny", dir.clone());
        cfg.steps = 6;
        cfg.keep_last = 2;
        cfg.mode = CkptRunMode::Sync;
        let mut t = Trainer::new(&m, cfg).unwrap();
        t.run().unwrap();
        let dirs: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_str().unwrap_or("").starts_with("step-"))
            .collect();
        assert!(dirs.len() <= 3, "pruning failed: {} dirs", dirs.len());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
