//! Synthetic token corpus for the end-to-end training runs.
//!
//! Deterministic, cursor-addressable (batch `k` is a pure function of
//! the seed and `k`), which is what makes the checkpointed `data_cursor`
//! meaningful: resuming from a checkpoint replays the exact remaining
//! sample stream.
//!
//! The corpus has learnable structure — sequences are noisy copies of a
//! small template bank, so a GPT can drive the loss well below the
//! uniform baseline ln(vocab) by memorizing the templates — while the
//! noise keeps the task non-degenerate.

use crate::util::rng::Rng;

/// Template-bank corpus generator.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    vocab: usize,
    seq: usize,
    batch: usize,
    seed: u64,
    templates: Vec<Vec<i32>>,
    /// Per-token probability of random corruption.
    noise: f64,
}

impl SyntheticCorpus {
    /// A corpus over `vocab` tokens producing `[batch, seq+1]` batches.
    pub fn new(vocab: usize, seq: usize, batch: usize, seed: u64) -> SyntheticCorpus {
        let mut rng = Rng::new(seed ^ 0xc0ffee);
        let n_templates = 8;
        let templates = (0..n_templates)
            .map(|_| {
                // templates built from a small alphabet subset → strong
                // token-level regularities to learn
                let alphabet: Vec<i32> =
                    (0..16).map(|_| rng.below(vocab as u64) as i32).collect();
                (0..seq + 1).map(|_| *rng.choose(&alphabet)).collect()
            })
            .collect();
        SyntheticCorpus { vocab, seq, batch, seed, templates, noise: 0.02 }
    }

    /// Batch `cursor` as a flat row-major [batch, seq+1] i32 buffer.
    pub fn batch_at(&self, cursor: u64) -> Vec<i32> {
        let mut rng = Rng::new(self.seed.wrapping_add(cursor.wrapping_mul(0x9e3779b97f4a7c15)));
        let mut out = Vec::with_capacity(self.batch * (self.seq + 1));
        for _ in 0..self.batch {
            let template = &self.templates[rng.below(self.templates.len() as u64) as usize];
            for &tok in template {
                if rng.bool(self.noise) {
                    out.push(rng.below(self.vocab as u64) as i32);
                } else {
                    out.push(tok);
                }
            }
        }
        out
    }

    /// `(batch, seq+1)` of every produced batch.
    pub fn shape(&self) -> (usize, usize) {
        (self.batch, self.seq + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_cursor_addressable() {
        let c = SyntheticCorpus::new(256, 32, 4, 7);
        assert_eq!(c.batch_at(5), c.batch_at(5));
        assert_ne!(c.batch_at(5), c.batch_at(6));
        // a fresh generator with the same seed agrees (resume semantics)
        let c2 = SyntheticCorpus::new(256, 32, 4, 7);
        assert_eq!(c.batch_at(123), c2.batch_at(123));
    }

    #[test]
    fn tokens_in_range_and_shape() {
        let c = SyntheticCorpus::new(256, 32, 4, 1);
        let b = c.batch_at(0);
        assert_eq!(b.len(), 4 * 33);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn corpus_has_learnable_structure() {
        // token distribution must be far from uniform (template reuse)
        let c = SyntheticCorpus::new(256, 32, 4, 2);
        let mut counts = vec![0usize; 256];
        for cursor in 0..50 {
            for &t in &c.batch_at(cursor) {
                counts[t as usize] += 1;
            }
        }
        let used = counts.iter().filter(|&&n| n > 0).count();
        // 8 templates × 16-symbol alphabets + noise: well under vocab
        assert!(used < 200, "used={used}");
    }
}
