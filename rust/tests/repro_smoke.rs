//! Integration: every paper experiment regenerates end-to-end and
//! produces a parseable result file.

use fastpersist::figures;
use fastpersist::util::json::Json;

#[test]
fn all_experiments_regenerate() {
    let dir = fastpersist::io::engine::scratch_dir("repro-smoke").unwrap();
    std::env::set_var("FASTPERSIST_RESULTS", &dir);
    figures::run_all(true).unwrap();
    for name in
        ["fig1", "fig2", "table1", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12"]
    {
        let path = dir.join(format!("{name}.json"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let v = Json::parse(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        let nonempty = match &v {
            Json::Array(a) => !a.is_empty(),
            Json::Object(o) => !o.is_empty(),
            _ => false,
        };
        assert!(nonempty, "{name} result is empty");
    }
    std::env::remove_var("FASTPERSIST_RESULTS");
    std::fs::remove_dir_all(&dir).unwrap();
}
