//! Integration: the persistent I/O runtime under concurrent load —
//! pipelined + direct checkpoints interleaved through ONE shared
//! runtime, multi-device striping with manifest-recorded assignments,
//! and zero steady-state staging allocations across the whole workload.

use std::collections::BTreeMap;
use std::sync::Arc;

use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::load::load_checkpoint;
use fastpersist::checkpoint::pipeline::PipelinedCheckpointer;
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::topology::RankPlacement;
use fastpersist::cluster::{ClusterSpec, Parallelism, Topology};
use fastpersist::io::device::{DeviceMap, DirectCapability};
use fastpersist::io::engine::{scratch_dir, EngineKind, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig, WriteJob};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;

fn store_with(seed: u64, nbytes: usize) -> TensorStore {
    let mut rng = Rng::new(seed);
    let mut s = TensorStore::new();
    let mut data = vec![0u8; nbytes];
    rng.fill_bytes(&mut data);
    s.push(Tensor::new("payload", DType::U8, vec![nbytes], data).unwrap()).unwrap();
    s
}

fn extra(step: i64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".into(), Json::Int(step));
    m
}

fn dp_group(dp: usize) -> Vec<RankPlacement> {
    Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(dp, 1, 1))
        .unwrap()
        .dp_group(0)
}

fn shared_runtime(devices: DeviceMap) -> Arc<IoRuntime> {
    Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        devices,
        ..IoRuntimeConfig::default()
    }))
}

#[test]
fn interleaved_pipelined_and_direct_checkpoints_share_one_runtime() {
    let dir = scratch_dir("it-shared-runtime").unwrap();
    let runtime = shared_runtime(DeviceMap::single());

    // Pipelined helper and direct engine both submit into the SAME
    // runtime's writer pool and staging buffers.
    let pipe_engine =
        CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas);
    let direct_engine =
        CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas);
    let mut pipe = PipelinedCheckpointer::new(pipe_engine, dp_group(2));

    let iters = 4i64;
    let mut pipe_stores = Vec::new();
    let mut direct_stores = Vec::new();
    for i in 0..iters {
        pipe.wait_previous().unwrap();
        let ps = store_with(100 + i as u64, 150_000);
        pipe.request(&ps, extra(i), dir.join(format!("pipe{i}"))).unwrap();
        pipe_stores.push(ps);
        // while the pipelined write is in flight, a direct checkpoint
        // of a different store goes through the same runtime
        let ds = store_with(200 + i as u64, 90_000);
        direct_engine
            .write(&ds, extra(i), &dir.join(format!("direct{i}")), &dp_group(4))
            .unwrap();
        direct_stores.push(ds);
    }
    let outcomes = pipe.finish().unwrap();
    assert_eq!(outcomes.len(), iters as usize);

    for i in 0..iters {
        let (loaded, header, _) =
            load_checkpoint(&dir.join(format!("pipe{i}")), &runtime).unwrap();
        assert!(loaded.content_eq(&pipe_stores[i as usize]), "pipe{i}");
        assert_eq!(header.extra["step"], Json::Int(i));
        let (loaded, header, _) =
            load_checkpoint(&dir.join(format!("direct{i}")), &runtime).unwrap();
        assert!(loaded.content_eq(&direct_stores[i as usize]), "direct{i}");
        assert_eq!(header.extra["step"], Json::Int(i));
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn steady_state_interleaving_never_allocates_staging_buffers() {
    let dir = scratch_dir("it-steady").unwrap();
    let runtime = shared_runtime(DeviceMap::single());
    let engine = CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas);

    // warm-up: one checkpoint plus a deterministic pool prewarm
    engine
        .write(&store_with(1, 120_000), extra(0), &dir.join("warm"), &dp_group(4))
        .unwrap();
    runtime.staging().prewarm();
    let allocs = runtime.staging().allocations();
    let acquires = runtime.staging().acquires();

    // three more checkpoints + concurrent direct writes from threads
    std::thread::scope(|scope| {
        for t in 0..3u64 {
            let engine = engine.clone();
            let d = dir.join(format!("t{t}"));
            scope.spawn(move || {
                let s = store_with(10 + t, 80_000);
                engine.write(&s, extra(t as i64), &d, &dp_group(2)).unwrap();
                let (loaded, _, _) = load_checkpoint(&d, engine.runtime()).unwrap();
                assert!(loaded.content_eq(&s));
            });
        }
    });
    assert_eq!(
        runtime.staging().allocations(),
        allocs,
        "no staging-buffer allocation allowed on the steady-state path"
    );
    assert!(runtime.staging().acquires() > acquires, "writes must recycle pooled buffers");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn multi_device_dp8_roundtrip_is_bit_identical() {
    // Acceptance: a DP=8 checkpoint striped across >= 2 DeviceMap mount
    // points reloads bit-identically via the manifest's recorded device
    // assignments.
    let base = scratch_dir("it-devmap8").unwrap();
    let devices = DeviceMap::simulated(2, &base.join("ssds")).unwrap();
    let runtime = shared_runtime(devices);
    let engine = CheckpointEngine::with_runtime(runtime, WriterStrategy::AllReplicas);

    let store = store_with(42, 500_000);
    let dir = base.join("ckpt");
    let out = engine.write(&store, extra(9), &dir, &dp_group(8)).unwrap();
    assert_eq!(out.stats.len(), 8);
    assert_eq!(out.manifest.devices().len(), 2, "both devices must be used");
    // partitions alternate across the two devices
    for (i, p) in out.manifest.partitions.iter().enumerate() {
        let root = p.device.as_deref().expect("device recorded");
        assert!(root.ends_with(&format!("ssd{}", i % 2)), "partition {i} on {root}");
    }

    let (loaded, header, manifest) = load_checkpoint(&dir, engine.runtime()).unwrap();
    assert!(loaded.content_eq(&store), "multi-device reload must be bit-identical");
    assert_eq!(header.extra["step"], Json::Int(9));
    assert_eq!(manifest.digest, out.manifest.digest);

    // integrity: corrupting a partition ON A DEVICE is caught at load
    let victim = &manifest.partitions[3];
    let vpath = fastpersist::checkpoint::load::partition_path(&dir, victim);
    let mut bytes = std::fs::read(&vpath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x5a;
    std::fs::write(&vpath, bytes).unwrap();
    assert!(
        load_checkpoint(&dir, engine.runtime()).is_err(),
        "digest must catch device-side corruption"
    );

    std::fs::remove_dir_all(&base).unwrap();
}

#[test]
fn all_engine_kinds_share_the_executor_and_produce_identical_files() {
    // Acceptance: every EngineKind runs through the single unified
    // executor and produces bit-identical bytes — durable config, so
    // the direct kinds exercise the probe-gated O_DIRECT/bounce path
    // wherever the scratch filesystem allows it.
    let dir = scratch_dir("it-unified").unwrap();
    let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist(), // durable, try_o_direct on
        ..IoRuntimeConfig::default()
    }));
    let mut data = vec![0u8; 1_000_000 + 4097]; // unaligned tail
    Rng::new(31).fill_bytes(&mut data);
    let data = Arc::new(data);
    for kind in [EngineKind::Buffered, EngineKind::DirectSingle, EngineKind::DirectDouble] {
        let path = dir.join(format!("{}.bin", kind.name()));
        let stats = rt
            .submit(WriteJob::bytes(Arc::clone(&data), path.clone()).with_kind(kind))
            .wait()
            .unwrap();
        assert_eq!(stats.total_bytes, data.len() as u64, "{kind:?}");
        assert_eq!(stats.fsyncs, 1, "{kind:?}: durable config fsyncs once");
        assert_eq!(
            std::fs::read(&path).unwrap(),
            *data,
            "{kind:?} must be bit-identical to the stream"
        );
        if stats.o_direct {
            // direct path engaged: aligned drains + bounce tail tile
            // the stream, and unaligned bytes never hit the direct fd
            assert!(stats.direct_bytes > 0, "{kind:?}");
            assert_eq!(stats.direct_bytes % 4096, 0, "{kind:?}: direct writes stay aligned");
            assert_eq!(stats.direct_bytes + stats.bounce_bytes, stats.total_bytes, "{kind:?}");
            assert!(stats.bounce_bytes < 4096, "{kind:?}: bounce carries only the tail");
        } else {
            assert_eq!(stats.direct_bytes, 0, "{kind:?}: probed fallback reports zero direct");
        }
        if kind == EngineKind::Buffered {
            assert_eq!(stats.direct_bytes, 0);
            assert_eq!(stats.queue_depth_max, 0, "streamed baseline has no submission queue");
        }
    }
    // the three kinds wrote identical files
    let b = std::fs::read(dir.join("buffered.bin")).unwrap();
    let s = std::fs::read(dir.join("direct-single.bin")).unwrap();
    let d = std::fs::read(dir.join("direct-double.bin")).unwrap();
    assert_eq!(b, s);
    assert_eq!(s, d);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn o_direct_probe_falls_back_with_reason_on_rejecting_fs() {
    // Satellite: CI determinism for the capability probe. /dev/shm is
    // tmpfs on Linux and rejects O_DIRECT at open; the probe must
    // report Unsupported with a non-empty reason (logged once), and a
    // durable direct write through a runtime on that device must engage
    // the buffered fallback (direct_bytes == 0, o_direct == false)
    // while still producing bit-identical bytes. On exotic setups where
    // the filesystem accepts O_DIRECT, the test degrades to checking
    // the supported path's accounting instead.
    let shm = std::path::Path::new("/dev/shm");
    if !shm.is_dir() {
        return; // no tmpfs mount to probe on this machine
    }
    let root = shm.join(format!("fp-probe-test-{}", std::process::id()));
    std::fs::create_dir_all(&root).unwrap();
    let devices = DeviceMap::from_roots(vec![root.clone()]).unwrap();
    let capability = devices.direct_capability_for(&root.join("f.bin"));
    assert_eq!(devices.probe().probed(), 1, "exactly one probe for the device");

    let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist(), // durable, try_o_direct on
        devices: devices.clone(),
        ..IoRuntimeConfig::default()
    }));
    let mut data = vec![0u8; 200_000 + 123];
    Rng::new(7).fill_bytes(&mut data);
    let data = Arc::new(data);
    let stats = rt.write_bytes(root.join("x.bin"), Arc::clone(&data)).unwrap();
    assert_eq!(std::fs::read(root.join("x.bin")).unwrap(), *data);
    match capability {
        DirectCapability::Unsupported(reason) => {
            assert!(!reason.is_empty(), "fallback must carry a logged reason");
            assert!(!stats.o_direct, "probed fallback must not engage O_DIRECT");
            assert_eq!(stats.direct_bytes, 0);
            assert_eq!(stats.direct_extents, 0);
            assert!(stats.aligned_bytes > 0, "fallback still drains aligned extents");
        }
        DirectCapability::Supported => {
            assert!(stats.o_direct, "probe said supported, write must use it");
            assert_eq!(stats.direct_bytes + stats.bounce_bytes, stats.total_bytes);
        }
    }
    // the capability was cached: the write did not re-probe
    assert_eq!(devices.probe().probed(), 1, "writes must reuse the cached probe");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn pipelined_checkpoints_stripe_across_devices_too() {
    let base = scratch_dir("it-devpipe").unwrap();
    let devices = DeviceMap::simulated(3, &base.join("ssds")).unwrap();
    let runtime = shared_runtime(devices);
    let engine = CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas);
    let mut pipe = PipelinedCheckpointer::new(engine, dp_group(4));

    let mut stores = Vec::new();
    for i in 0..3i64 {
        pipe.wait_previous().unwrap();
        let s = store_with(300 + i as u64, 120_000);
        pipe.request(&s, extra(i), base.join(format!("ck{i}"))).unwrap();
        stores.push(s);
    }
    let outcomes = pipe.finish().unwrap();
    for (i, out) in outcomes.iter().enumerate() {
        assert_eq!(out.manifest.devices().len(), 3, "ck{i} must stripe over all devices");
        let (loaded, _, _) = load_checkpoint(&base.join(format!("ck{i}")), &runtime).unwrap();
        assert!(loaded.content_eq(&stores[i]));
    }
    std::fs::remove_dir_all(&base).unwrap();
}
