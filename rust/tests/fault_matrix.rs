//! Durability scenario matrix: deterministic fault injection over the
//! write pipeline's op schedule.
//!
//! Five representative plan shapes — full sync, staged direct I/O
//! (queue depth ≥ 2), delta chain base+Δ+Δ, lazy multi-generation, and
//! segment-GC sparse rewrite — are first probed with a disarmed
//! `FaultPlan` to enumerate every Stage/Drain/Fsync/Publish (and, for
//! GC, GcCopy) boundary of their realized schedules, then re-run with
//! each fault kind armed at each boundary. After every injection the
//! durability invariant is checked:
//!
//! * recovery lands on the newest *published* generation — manifest
//!   present, loads bit-identically to its captured snapshot;
//! * partially written generations are invisible — no manifest, not
//!   loadable, skipped by discovery;
//! * a restarted writer continues the chain from the recovery point.
//!
//! The quick (CI) sweep injects at the first, middle, and last boundary
//! of every site class; `FAULT_MATRIX_FULL=1` extends that to every
//! boundary index plus a seeded sweep through `FaultPlan::seeded`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use fastpersist::checkpoint::codec::CodecKind;
use fastpersist::checkpoint::delta::{
    prune_chain_injected, DeltaCheckpointer, DeltaConfig, GcPolicy,
};
use fastpersist::checkpoint::lazy::{LazyCheckpointer, LazyConfig};
use fastpersist::checkpoint::load::load_checkpoint;
use fastpersist::checkpoint::manifest::MANIFEST_FILE;
use fastpersist::checkpoint::{CheckpointEngine, WriterStrategy};
use fastpersist::io::device::DeviceMap;
use fastpersist::io::engine::{scratch_dir, EngineKind, IoConfig};
use fastpersist::io::fault::{FaultKind, FaultPlan, FaultSite};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::training::looper::Trainer;
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;

const CS: u64 = 4096;
/// Small staging buffer so even a few tens of KiB cross several
/// Stage/Drain boundaries per file.
const BUF: usize = 16 << 10;

fn full_sweep() -> bool {
    std::env::var("FAULT_MATRIX_FULL").ok().as_deref() == Some("1")
}

/// Single-threaded, durable (fsync on) runtime so the op schedule — and
/// with it every boundary index — is deterministic across runs.
fn runtime_with(kind: EngineKind, fault: Option<FaultPlan>) -> Arc<IoRuntime> {
    Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig { kind, io_buf_size: BUF, fault, ..IoConfig::default() },
        writer_threads: 1,
        drain_threads: 1,
        ..IoRuntimeConfig::default()
    }))
}

fn delta_writer(rt: &Arc<IoRuntime>, max_chain: u64) -> DeltaCheckpointer {
    DeltaCheckpointer::new(
        Arc::clone(rt),
        DeltaConfig { chunk_size: CS, max_chain, ..DeltaConfig::default() },
    )
}

fn qdelta_writer(rt: &Arc<IoRuntime>, max_chain: u64) -> DeltaCheckpointer {
    DeltaCheckpointer::new(
        Arc::clone(rt),
        DeltaConfig {
            chunk_size: CS,
            max_chain,
            codec: CodecKind::QuantDelta,
            ..DeltaConfig::default()
        },
    )
}

fn store(seed: u64, nbytes: usize) -> TensorStore {
    let mut rng = Rng::new(seed);
    let mut s = TensorStore::new();
    let mut data = vec![0u8; nbytes];
    rng.fill_bytes(&mut data);
    s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
    s
}

fn mutate(s: &mut TensorStore, frac: f64, tag: u8) {
    let t = s.get("w").unwrap();
    let mut data = t.data.as_slice().to_vec();
    let n = (data.len() as f64 * frac) as usize;
    let start = data.len() / 4;
    for b in &mut data[start..start + n] {
        *b ^= tag | 1;
    }
    s.update("w", data).unwrap();
}

/// Small-magnitude scattered updates (bump one byte every 64 across a
/// sliding window): the dirty chunks' diffs against their previously
/// stored bytes are mostly zero runs, so the qdelta codec actually
/// encodes them instead of the benefit gate falling back to raw.
fn scatter_mutate(s: &mut TensorStore, step: u64) {
    let t = s.get("w").unwrap();
    let mut data = t.data.as_slice().to_vec();
    let start = (step as usize * 3 * CS as usize) % (data.len() / 2);
    let end = (start + 4 * CS as usize).min(data.len());
    let mut off = start;
    while off < end {
        data[off] = data[off].wrapping_add(1);
        off += 64;
    }
    s.update("w", data).unwrap();
}

fn extra(step: i64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step));
    m
}

fn step_dir(dir: &Path, step: i64) -> PathBuf {
    dir.join(format!("step-{step:08}"))
}

// ---------------------------------------------------------------- shapes

/// Full synchronous checkpoints through the buffered (torch.save-style)
/// engine: Stage/Drain/Fsync once per step, manifest published last.
fn run_full(fault: FaultPlan, dir: &Path) -> Vec<(i64, TensorStore)> {
    let rt = runtime_with(EngineKind::Buffered, Some(fault));
    let engine = CheckpointEngine::with_runtime(rt, WriterStrategy::Rank0);
    let mut s = store(11, 12 * CS as usize);
    let mut snaps = Vec::new();
    for step in 1..=2i64 {
        let _ = engine.write_single(&s, extra(step), &step_dir(dir, step));
        snaps.push((step, s.snapshot()));
        mutate(&mut s, 0.2, step as u8);
    }
    snaps
}

/// Full checkpoints through the staged double-buffered direct engine:
/// several Stage/Drain boundaries per step (payload spans ≥ 3 staging
/// buffers), queue depth 2.
fn run_staged(fault: FaultPlan, dir: &Path) -> Vec<(i64, TensorStore)> {
    let rt = runtime_with(EngineKind::DirectDouble, Some(fault));
    let engine = CheckpointEngine::with_runtime(rt, WriterStrategy::Rank0);
    let mut s = store(17, 12 * CS as usize);
    let mut snaps = Vec::new();
    for step in 1..=2i64 {
        let _ = engine.write_single(&s, extra(step), &step_dir(dir, step));
        snaps.push((step, s.snapshot()));
        mutate(&mut s, 0.2, step as u8);
    }
    snaps
}

/// Incremental chain base+Δ+Δ: segment writes ride the staged pipeline,
/// each link commits with its own manifest publish.
fn run_delta(fault: FaultPlan, dir: &Path) -> Vec<(i64, TensorStore)> {
    let rt = runtime_with(EngineKind::DirectDouble, Some(fault));
    let mut ck = delta_writer(&rt, 8);
    let mut s = store(23, 12 * CS as usize);
    let mut snaps = Vec::new();
    for step in 1..=3i64 {
        let _ = ck.write(&s, extra(step), &step_dir(dir, step));
        snaps.push((step, s.snapshot()));
        mutate(&mut s, 0.05, step as u8);
    }
    snaps
}

/// Quantized-delta chain base+Δ+Δ: dirty chunks store encoded diffs
/// against base extents in older directories, so every
/// Stage/Drain/Fsync/Publish boundary is crossed with codec metadata in
/// flight — and recovery must *decode* through surviving base
/// references to prove the durable generation bit-exact.
fn run_qdelta(fault: FaultPlan, dir: &Path) -> Vec<(i64, TensorStore)> {
    let rt = runtime_with(EngineKind::DirectDouble, Some(fault));
    let mut ck = qdelta_writer(&rt, 8);
    let mut s = store(41, 12 * CS as usize);
    let mut snaps = Vec::new();
    for step in 1..=3i64 {
        let _ = ck.write(&s, extra(step), &step_dir(dir, step));
        snaps.push((step, s.snapshot()));
        scatter_mutate(&mut s, step as u64);
    }
    snaps
}

/// Lazy asynchronous captures flushed as a delta chain on the scheduler
/// thread: the fault fires mid-flush while the trainer keeps stepping.
fn run_lazy(fault: FaultPlan, dir: &Path) -> Vec<(i64, TensorStore)> {
    let rt = runtime_with(EngineKind::DirectDouble, Some(fault));
    let cfg = LazyConfig { staging_bytes: 2 << 20, buf_size: 256 << 10, max_generations: 2 };
    let mut lazy = LazyCheckpointer::delta(delta_writer(&rt, 8), cfg);
    let mut s = store(31, 12 * CS as usize);
    let mut snaps = Vec::new();
    for step in 1..=3i64 {
        // post-fault captures may surface the flush failure through
        // backpressure — tolerated, the disk state is what's verified
        let _ = lazy.capture(&s, extra(step), step_dir(dir, step));
        snaps.push((step, s.snapshot()));
        mutate(&mut s, 0.05, step as u8);
    }
    while lazy.in_flight() > 0 {
        let _ = lazy.wait_all();
    }
    snaps
}

/// Chain with compaction (base, Δ, Δ, fresh base) followed by a pruning
/// pass whose sparse segment rewrite crosses GcCopy boundaries.
fn run_gc(fault: FaultPlan, dir: &Path) -> Vec<(i64, TensorStore)> {
    let rt = runtime_with(EngineKind::DirectDouble, Some(fault.clone()));
    let mut ck = delta_writer(&rt, 2);
    let mut s = store(13, 16 * CS as usize);
    let mut snaps = Vec::new();
    for step in 1..=4i64 {
        let _ = ck.write(&s, extra(step), &step_dir(dir, step));
        snaps.push((step, s.snapshot()));
        mutate(&mut s, 0.06, step as u8);
    }
    let _ = prune_chain_injected(
        dir,
        2,
        &DeviceMap::single(),
        Some(4),
        GcPolicy { occupancy: 1.0 },
        Some(&fault),
    );
    snaps
}

// ------------------------------------------------------------- restarts

/// Restarted full writer: publishes one more step and recovery moves to
/// it.
fn restart_full_with(kind: EngineKind, dir: &Path, snaps: &[(i64, TensorStore)]) {
    let rt = runtime_with(kind, None);
    let engine = CheckpointEngine::with_runtime(Arc::clone(&rt), WriterStrategy::Rank0);
    let (last, state) = snaps.last().expect("scenario ran");
    let next = last + 1;
    let mut s = state.snapshot();
    mutate(&mut s, 0.2, 9);
    engine.write_single(&s, extra(next), &step_dir(dir, next)).expect("restarted writer");
    let latest = Trainer::latest_checkpoint(dir).unwrap().expect("restart published");
    assert!(latest.ends_with(format!("step-{next:08}")), "latest = {latest:?}");
    let (loaded, _, _) = load_checkpoint(&latest, &rt).expect("restart must load");
    assert!(loaded.content_eq(&s));
}

fn restart_full(_fault: &FaultPlan, dir: &Path, snaps: &[(i64, TensorStore)]) {
    restart_full_with(EngineKind::Buffered, dir, snaps);
}

fn restart_staged(_fault: &FaultPlan, dir: &Path, snaps: &[(i64, TensorStore)]) {
    restart_full_with(EngineKind::DirectDouble, dir, snaps);
}

/// Restarted delta writer: re-attaches to the recovery point when one
/// exists (continuing the chain, not restarting it) and publishes one
/// more loadable step.
fn restart_delta(_fault: &FaultPlan, dir: &Path, snaps: &[(i64, TensorStore)]) {
    let rt = runtime_with(EngineKind::DirectDouble, None);
    let ck = delta_writer(&rt, 8);
    restart_chain(ck, &rt, dir, snaps);
}

/// Restarted quantized-delta writer: resume drops the in-memory diff
/// references (the next write's dirty chunks degrade to raw storage)
/// but must still continue the chain and publish a loadable step whose
/// *inherited* chunks decode through their recorded base refs.
fn restart_qdelta(_fault: &FaultPlan, dir: &Path, snaps: &[(i64, TensorStore)]) {
    let rt = runtime_with(EngineKind::DirectDouble, None);
    let ck = qdelta_writer(&rt, 8);
    restart_chain(ck, &rt, dir, snaps);
}

fn restart_chain(
    mut ck: DeltaCheckpointer,
    rt: &Arc<IoRuntime>,
    dir: &Path,
    snaps: &[(i64, TensorStore)],
) {
    let latest = Trainer::latest_checkpoint(dir).unwrap();
    let resumed = match &latest {
        Some(l) => ck.resume_from(l).expect("resume from published checkpoint"),
        None => false,
    };
    let (last, state) = snaps.last().expect("scenario ran");
    let next = last + 1;
    let mut s = state.snapshot();
    mutate(&mut s, 0.05, 9);
    let out = ck.write(&s, extra(next), &step_dir(dir, next)).expect("restarted writer");
    assert_eq!(out.is_base, !resumed, "restart must continue a resumable chain");
    let (loaded, _, _) = load_checkpoint(&step_dir(dir, next), rt).expect("restart must load");
    assert!(loaded.content_eq(&s));
    let newest = Trainer::latest_checkpoint(dir).unwrap().expect("restart published");
    assert!(newest.ends_with(format!("step-{next:08}")), "latest = {newest:?}");
}

/// Everything under `dir` named like a half-built GC rewrite temp.
fn gc_orphans(dir: &Path) -> Vec<PathBuf> {
    let mut found = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&d) else { continue };
        for e in entries.flatten() {
            let p = e.path();
            if p.is_dir() {
                stack.push(p);
            } else if p
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.ends_with(".fpseg.gc"))
            {
                found.push(p);
            }
        }
    }
    found
}

/// GC epilogue: the next prune must converge — sweep any orphaned
/// rewrite temp the injected crash left behind, finish the reclaim, and
/// keep every surviving checkpoint loadable — before the usual restart.
fn converge_gc(fault: &FaultPlan, dir: &Path, snaps: &[(i64, TensorStore)]) {
    prune_chain_injected(
        dir,
        2,
        &DeviceMap::single(),
        Some(4),
        GcPolicy { occupancy: 1.0 },
        Some(fault),
    )
    .expect("healed prune must converge");
    let orphans = gc_orphans(dir);
    assert!(orphans.is_empty(), "GC temp orphans must not survive the next prune: {orphans:?}");
    restart_delta(fault, dir, snaps);
}

// --------------------------------------------------------------- driver

struct Scenario {
    name: &'static str,
    cells: &'static [(FaultKind, FaultSite)],
    run: fn(FaultPlan, &Path) -> Vec<(i64, TensorStore)>,
    epilogue: fn(&FaultPlan, &Path, &[(i64, TensorStore)]),
}

/// Kind × site cells every write shape is swept through.
const WRITE_CELLS: &[(FaultKind, FaultSite)] = &[
    (FaultKind::Abort, FaultSite::Stage),
    (FaultKind::Abort, FaultSite::Drain),
    (FaultKind::Abort, FaultSite::Fsync),
    (FaultKind::Abort, FaultSite::Publish),
    (FaultKind::TornWrite, FaultSite::Drain),
    (FaultKind::ShortFsync, FaultSite::Fsync),
    (FaultKind::StaleManifest, FaultSite::Publish),
];

/// The GC shape additionally sweeps the sparse-rewrite copy loop.
const GC_CELLS: &[(FaultKind, FaultSite)] = &[
    (FaultKind::Abort, FaultSite::Stage),
    (FaultKind::Abort, FaultSite::Drain),
    (FaultKind::Abort, FaultSite::Fsync),
    (FaultKind::Abort, FaultSite::Publish),
    (FaultKind::TornWrite, FaultSite::Drain),
    (FaultKind::ShortFsync, FaultSite::Fsync),
    (FaultKind::StaleManifest, FaultSite::Publish),
    (FaultKind::Abort, FaultSite::GcCopy),
    (FaultKind::TornWrite, FaultSite::GcCopy),
];

/// Quick sweep: first, middle, last boundary. Full sweep: all of them.
fn pick_indices(n: u64) -> Vec<u64> {
    if full_sweep() {
        (0..n).collect()
    } else {
        let mut v = vec![0, n / 2, n.saturating_sub(1)];
        v.dedup();
        v
    }
}

/// The durability invariant, checked from disk state alone: every
/// manifest-bearing step loads bit-identically to its captured
/// snapshot, every manifest-less step is unloadable, and discovery
/// lands on the newest published step.
fn verify_durability(dir: &Path, snaps: &[(i64, TensorStore)], ctx: &str) {
    let rt = runtime_with(EngineKind::DirectDouble, None);
    let mut expect_latest: Option<PathBuf> = None;
    for (step, snap) in snaps {
        let d = step_dir(dir, *step);
        if d.join(MANIFEST_FILE).exists() {
            let (loaded, header, _) = load_checkpoint(&d, &rt)
                .unwrap_or_else(|e| panic!("{ctx}: published step {step} must load: {e}"));
            assert!(
                loaded.content_eq(snap),
                "{ctx}: published step {step} must match its captured snapshot"
            );
            assert_eq!(header.extra["step"], Json::Int(*step), "{ctx}: step {step} extras");
            expect_latest = Some(d);
        } else {
            assert!(
                load_checkpoint(&d, &rt).is_err(),
                "{ctx}: unpublished step {step} must not load"
            );
        }
    }
    let latest = Trainer::latest_checkpoint(dir).unwrap();
    assert_eq!(latest, expect_latest, "{ctx}: recovery must land on the newest published step");
}

fn run_cell(s: &Scenario, root: &Path, ctx: &str, kind: FaultKind, fault: FaultPlan) {
    let dir = root.join(ctx.replace(['/', '@', '[', ']', '#'], "-"));
    let snaps = (s.run)(fault.clone(), &dir);
    assert!(fault.tripped(), "{ctx}: armed fault must fire");
    match kind {
        FaultKind::Abort | FaultKind::TornWrite => {
            assert!(fault.halted(), "{ctx}: {} must simulate process death", kind.name());
        }
        FaultKind::ShortFsync => {
            assert_eq!(fault.skipped_fsyncs(), 1, "{ctx}: exactly one fsync elided");
        }
        FaultKind::StaleManifest => {
            assert_eq!(fault.suppressed_publishes(), 1, "{ctx}: exactly one publish suppressed");
        }
    }
    fault.heal();
    verify_durability(&dir, &snaps, ctx);
    (s.epilogue)(&fault, &dir, &snaps);
    let _ = std::fs::remove_dir_all(&dir);
}

fn run_matrix(s: &Scenario) {
    let root = scratch_dir(&format!("fault-matrix-{}", s.name)).unwrap();
    // Probe pass: enumerate the shape's op schedule with a disarmed
    // plan, and confirm the fault-free run is fully durable.
    let probe = FaultPlan::observe();
    let probe_dir = root.join("probe");
    let snaps = (s.run)(probe.clone(), &probe_dir);
    assert!(!probe.tripped() && !probe.halted(), "observe() must never fire");
    verify_durability(&probe_dir, &snaps, &format!("{}/probe", s.name));
    let _ = std::fs::remove_dir_all(&probe_dir);

    for &(kind, site) in s.cells {
        let n = probe.boundaries(site);
        assert!(n > 0, "{}: shape never crosses a {} boundary", s.name, site.name());
        for nth in pick_indices(n) {
            let ctx = format!("{}/{}@{}[{nth}]", s.name, kind.name(), site.name());
            run_cell(s, &root, &ctx, kind, FaultPlan::fire_at(kind, site, nth));
        }
        if full_sweep() {
            for seed in [0x5eed_0001u64, 0xfa57_9e12] {
                let ctx = format!("{}/{}@{}#seed{seed:x}", s.name, kind.name(), site.name());
                run_cell(s, &root, &ctx, kind, FaultPlan::seeded(seed, kind, site, n));
            }
        }
    }
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------- tests

#[test]
fn full_sync_plan_survives_every_fault_boundary() {
    run_matrix(&Scenario {
        name: "full-sync",
        cells: WRITE_CELLS,
        run: run_full,
        epilogue: restart_full,
    });
}

#[test]
fn staged_direct_plan_survives_every_fault_boundary() {
    run_matrix(&Scenario {
        name: "staged-direct",
        cells: WRITE_CELLS,
        run: run_staged,
        epilogue: restart_staged,
    });
}

#[test]
fn delta_chain_plan_survives_every_fault_boundary() {
    run_matrix(&Scenario {
        name: "delta-chain",
        cells: WRITE_CELLS,
        run: run_delta,
        epilogue: restart_delta,
    });
}

#[test]
fn qdelta_chain_plan_survives_every_fault_boundary() {
    run_matrix(&Scenario {
        name: "qdelta-chain",
        cells: WRITE_CELLS,
        run: run_qdelta,
        epilogue: restart_qdelta,
    });
}

#[test]
fn lazy_multi_generation_plan_survives_every_fault_boundary() {
    run_matrix(&Scenario {
        name: "lazy-multi-gen",
        cells: WRITE_CELLS,
        run: run_lazy,
        epilogue: restart_delta,
    });
}

#[test]
fn gc_sparse_rewrite_survives_every_fault_boundary() {
    run_matrix(&Scenario {
        name: "gc-rewrite",
        cells: GC_CELLS,
        run: run_gc,
        epilogue: converge_gc,
    });
}
