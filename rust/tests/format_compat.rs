//! On-disk format backward compatibility.
//!
//! `rust/tests/fixtures/v3/` holds a checked-in two-checkpoint delta
//! chain in the **manifest v3** layout (uniform whole-stream chunk
//! grid, one `chunk-NNNNNN.fpck` file per chunk) exactly as written by
//! the pre-segment-store code. The current (v4, segment-file) reader
//! must keep reloading it bit-identically — see `docs/FORMATS.md` for
//! the version matrix.

use std::path::PathBuf;
use std::sync::Arc;

use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::load::load_checkpoint;
use fastpersist::checkpoint::manifest::CheckpointManifest;
use fastpersist::io::engine::IoConfig;
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::json::Json;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/v3")
}

/// The deterministic tensor the fixture generator serialized: byte `i`
/// is `(i * 131 + 7) % 256`, with step 2 XOR-ing `0x5a` over the 10%
/// region starting at one third.
fn expected_store(mutated: bool) -> TensorStore {
    let nbytes = 6 * 4096 + 777;
    let mut data: Vec<u8> = (0..nbytes).map(|i| ((i * 131 + 7) % 256) as u8).collect();
    if mutated {
        let start = nbytes / 3;
        let n = nbytes / 10;
        for b in &mut data[start..start + n] {
            *b ^= 0x5a;
        }
    }
    let mut s = TensorStore::new();
    s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
    s
}

#[test]
fn v3_per_chunk_file_checkpoints_reload_bit_identically() {
    let dir = fixture_dir();
    assert!(dir.join("step-00000001").is_dir(), "fixture missing: {dir:?}");

    // the base (all chunks local, per-chunk files)
    let (base, header, manifest) = load_checkpoint(&dir.join("step-00000001"), 3).unwrap();
    assert!(base.content_eq(&expected_store(false)), "v3 base reload diverged");
    assert_eq!(header.extra["step"], Json::Int(1));
    let delta = manifest.delta.as_ref().expect("fixture base is a delta-layout manifest");
    assert_eq!(delta.header_len, 0, "v3 manifests use the legacy uniform grid");
    assert!(delta.chunks.iter().all(|c| c.seg.is_none()), "v3 chunks carry no segment refs");

    // the delta link: clean chunks resolved from the sibling base dir
    let (linked, header, manifest) = load_checkpoint(&dir.join("step-00000002"), 3).unwrap();
    assert!(linked.content_eq(&expected_store(true)), "v3 delta reload diverged");
    assert_eq!(header.extra["step"], Json::Int(2));
    let delta = manifest.delta.as_ref().unwrap();
    assert_eq!(delta.chain_len, 1);
    assert_eq!(delta.base.as_deref(), Some("step-00000001"));
    assert!(delta.chunks.iter().any(|c| c.source.is_some()), "delta must inherit chunks");
}

#[test]
fn v3_manifest_does_not_seed_a_v4_chain() {
    // A restarted writer pointed at a v3 checkpoint must fall back to
    // base mode (its uniform grid cannot seed the header-split segment
    // diff) rather than silently producing a mixed-layout chain.
    let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        ..IoRuntimeConfig::default()
    }));
    let mut ck = DeltaCheckpointer::new(
        rt,
        DeltaConfig { chunk_size: 4096, max_chain: 8, ..DeltaConfig::default() },
    );
    let resumed = ck.resume_from(&fixture_dir().join("step-00000002")).unwrap();
    assert!(!resumed, "v3 manifests must not be adopted as chain predecessors");
    assert_eq!(ck.chain_len(), None);
}

#[test]
fn fixture_manifest_reports_version_3() {
    let text =
        std::fs::read_to_string(fixture_dir().join("step-00000002/checkpoint.json")).unwrap();
    let v = Json::parse(&text).unwrap();
    assert_eq!(v.get("manifest_version").unwrap().as_i64().unwrap(), 3);
    // and the current writer emits v4
    assert_eq!(fastpersist::checkpoint::manifest::MANIFEST_VERSION, 4);
    let _ = CheckpointManifest::from_json(&v).unwrap();
}
