//! On-disk format backward compatibility.
//!
//! `rust/tests/fixtures/v3/` holds a checked-in two-checkpoint delta
//! chain in the **manifest v3** layout (uniform whole-stream chunk
//! grid, one `chunk-NNNNNN.fpck` file per chunk) exactly as written by
//! the pre-segment-store code, `rust/tests/fixtures/v4/` the same
//! logical chain in the **manifest v4** segment-store layout (FPSG
//! segment files, header-split grid, JSON `chunks` array), and
//! `rust/tests/fixtures/v5/` the same chain again with the **manifest
//! v5** binary chunk table (hex blob of 36-byte LE records + interned
//! string tables + table digest), and `rust/tests/fixtures/v6/` the
//! chain once more with the **manifest v6** codec-carrying records
//! (76-byte LE: codec id + encoded length + qdelta base reference) and
//! every chunk stored through the in-repo `lz4` block codec. The
//! current ReadRuntime-based loader must keep reloading all four
//! bit-identically — see `docs/FORMATS.md` for the version matrix.
//!
//! The v6 fixture was produced by the `generate_v6_fixture` test below
//! (`cargo test --test format_compat -- --ignored generate_v6_fixture`);
//! the v3/v4/v5 fixtures are frozen artifacts of older writers,
//! regenerable only via the committed `gen_v4_fixture.py` /
//! `gen_v5_fixture.py` / `gen_v6_fixture.py` scripts. Regenerate a
//! fixture only when the *writer* intentionally changes layout, never
//! to make the reader pass.
//!
//! The corruption fuzz runs 29 scattered byte flips per target by
//! default; set `FASTPERSIST_FUZZ_FULL=1` (the nightly CI sweep) for a
//! denser 257-flip pass.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use fastpersist::checkpoint::codec::CodecKind;
use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::load::{load_checkpoint, load_checkpoint_with, RestoreOptions};
use fastpersist::checkpoint::manifest::CheckpointManifest;
use fastpersist::checkpoint::{CheckpointEngine, WriterStrategy};
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::json::Json;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/v3")
}

fn fixture_dir_v4() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/v4")
}

fn fixture_dir_v5() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/v5")
}

fn fixture_dir_v6() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/v6")
}

fn runtime() -> Arc<IoRuntime> {
    Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        ..IoRuntimeConfig::default()
    }))
}

/// The deterministic tensor the fixture generators serialized: byte `i`
/// is `(i * 131 + 7) % 256`, with step 2 XOR-ing `0x5a` over the 10%
/// region starting at one third.
fn expected_store(mutated: bool) -> TensorStore {
    let nbytes = 6 * 4096 + 777;
    let mut data: Vec<u8> = (0..nbytes).map(|i| ((i * 131 + 7) % 256) as u8).collect();
    if mutated {
        let start = nbytes / 3;
        let n = nbytes / 10;
        for b in &mut data[start..start + n] {
            *b ^= 0x5a;
        }
    }
    let mut s = TensorStore::new();
    s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
    s
}

#[test]
fn v3_per_chunk_file_checkpoints_reload_bit_identically() {
    let dir = fixture_dir();
    assert!(dir.join("step-00000001").is_dir(), "fixture missing: {dir:?}");
    let rt = runtime();

    // the base (all chunks local, per-chunk files)
    let (base, header, manifest) = load_checkpoint(&dir.join("step-00000001"), &rt).unwrap();
    assert!(base.content_eq(&expected_store(false)), "v3 base reload diverged");
    assert_eq!(header.extra["step"], Json::Int(1));
    let delta = manifest.delta.as_ref().expect("fixture base is a delta-layout manifest");
    assert_eq!(delta.header_len, 0, "v3 manifests use the legacy uniform grid");
    assert!(delta.chunks.iter().all(|c| c.seg.is_none()), "v3 chunks carry no segment refs");

    // the delta link: clean chunks resolved from the sibling base dir
    let (linked, header, manifest) = load_checkpoint(&dir.join("step-00000002"), &rt).unwrap();
    assert!(linked.content_eq(&expected_store(true)), "v3 delta reload diverged");
    assert_eq!(header.extra["step"], Json::Int(2));
    let delta = manifest.delta.as_ref().unwrap();
    assert_eq!(delta.chain_len, 1);
    assert_eq!(delta.base.as_deref(), Some("step-00000001"));
    assert!(delta.chunks.iter().any(|c| c.source.is_some()), "delta must inherit chunks");
}

#[test]
fn v4_segment_checkpoints_reload_bit_identically() {
    let dir = fixture_dir_v4();
    assert!(dir.join("step-00000001").is_dir(), "fixture missing: {dir:?}");
    let rt = runtime();

    // the base: all chunks local, packed into segment files
    let loaded =
        load_checkpoint_with(&dir.join("step-00000001"), &rt, RestoreOptions::default()).unwrap();
    assert!(loaded.store.content_eq(&expected_store(false)), "v4 base reload diverged");
    assert_eq!(loaded.header.extra["step"], Json::Int(1));
    let delta = loaded.manifest.delta.as_ref().expect("v4 base carries a delta section");
    assert!(delta.header_len > 0, "v4 manifests use the header-split grid");
    assert!(delta.chunks.iter().all(|c| c.seg.is_some()), "v4 chunks carry segment refs");
    // chunk-hash verification is folded into the read pass, and the
    // base's byte-adjacent chunks coalesce below one pread per chunk
    assert_eq!(loaded.stats.chunks_verified as usize, delta.chunks.len());
    assert!(
        loaded.stats.preads < delta.chunks.len() as u64,
        "adjacent v4 chunks must coalesce: {} preads for {} chunks",
        loaded.stats.preads,
        delta.chunks.len()
    );

    // the delta link: inherited chunks resolve into the base's segments
    let (linked, header, manifest) = load_checkpoint(&dir.join("step-00000002"), &rt).unwrap();
    assert!(linked.content_eq(&expected_store(true)), "v4 delta reload diverged");
    assert_eq!(header.extra["step"], Json::Int(2));
    let delta = manifest.delta.as_ref().unwrap();
    assert_eq!(delta.chain_len, 1);
    assert_eq!(delta.base.as_deref(), Some("step-00000001"));
    assert!(delta.chunks.iter().any(|c| c.source.is_some()), "delta must inherit chunks");
}

#[test]
fn v5_binary_table_checkpoints_reload_bit_identically() {
    let dir = fixture_dir_v5();
    assert!(dir.join("step-00000001").is_dir(), "fixture missing: {dir:?}");
    let rt = runtime();

    // the base: all chunks local, table decoded from the binary blob
    let loaded =
        load_checkpoint_with(&dir.join("step-00000001"), &rt, RestoreOptions::default()).unwrap();
    assert!(loaded.store.content_eq(&expected_store(false)), "v5 base reload diverged");
    assert_eq!(loaded.header.extra["step"], Json::Int(1));
    let delta = loaded.manifest.delta.as_ref().expect("v5 base carries a delta section");
    assert!(delta.header_len > 0, "v5 manifests use the header-split grid");
    assert!(delta.chunks.iter().all(|c| c.seg.is_some()), "v5 chunks carry segment refs");
    assert!(delta.chunks.iter().all(|c| c.source.is_none()), "base chunks are all local");
    assert_eq!(loaded.stats.chunks_verified as usize, delta.chunks.len());

    // the delta link: inherited chunks carry interned source names
    let (linked, header, manifest) = load_checkpoint(&dir.join("step-00000002"), &rt).unwrap();
    assert!(linked.content_eq(&expected_store(true)), "v5 delta reload diverged");
    assert_eq!(header.extra["step"], Json::Int(2));
    let delta = manifest.delta.as_ref().unwrap();
    assert_eq!(delta.chain_len, 1);
    assert_eq!(delta.base.as_deref(), Some("step-00000001"));
    assert!(
        delta.chunks.iter().any(|c| c.source.as_deref() == Some("step-00000001")),
        "delta must inherit chunks through the sources table"
    );
}

#[test]
fn v6_codec_table_checkpoints_reload_bit_identically() {
    let dir = fixture_dir_v6();
    assert!(dir.join("step-00000001").is_dir(), "fixture missing: {dir:?}");
    let rt = runtime();

    // the base: every chunk of the committed fixture is lz4-encoded,
    // so the whole restore flows through the decode stage — and must
    // still come back bit-identical with every raw hash verified
    let loaded =
        load_checkpoint_with(&dir.join("step-00000001"), &rt, RestoreOptions::default()).unwrap();
    assert!(loaded.store.content_eq(&expected_store(false)), "v6 base reload diverged");
    assert_eq!(loaded.header.extra["step"], Json::Int(1));
    let delta = loaded.manifest.delta.as_ref().expect("v6 base carries a delta section");
    assert!(
        delta.chunks.iter().all(|c| c.codec == CodecKind::Lz4 && c.enc_len < c.len),
        "the committed v6 base stores every chunk lz4-encoded and shrunk"
    );
    assert!(delta.chunks.iter().all(|c| c.base.is_none()), "lz4 chunks carry no base refs");
    assert_eq!(loaded.stats.chunks_verified as usize, delta.chunks.len());
    assert_eq!(loaded.stats.chunks_decoded as usize, delta.chunks.len());
    assert!(
        loaded.stats.bytes_encoded > 0 && loaded.stats.bytes_encoded < loaded.stats.bytes,
        "decode stats must show fewer encoded than raw bytes ({} / {})",
        loaded.stats.bytes_encoded,
        loaded.stats.bytes
    );

    // the delta link: inherited chunks keep the codec of wherever
    // their bytes physically live (the base's segment store)
    let (linked, header, manifest) = load_checkpoint(&dir.join("step-00000002"), &rt).unwrap();
    assert!(linked.content_eq(&expected_store(true)), "v6 delta reload diverged");
    assert_eq!(header.extra["step"], Json::Int(2));
    let delta = manifest.delta.as_ref().unwrap();
    assert_eq!(delta.chain_len, 1);
    assert_eq!(delta.base.as_deref(), Some("step-00000001"));
    assert!(
        delta
            .chunks
            .iter()
            .any(|c| c.source.as_deref() == Some("step-00000001") && c.codec == CodecKind::Lz4),
        "inherited chunks must keep the codec fields of their source"
    );
}

#[test]
fn v3_manifest_does_not_seed_a_v4_chain() {
    // A restarted writer pointed at a v3 checkpoint must fall back to
    // base mode (its uniform grid cannot seed the header-split segment
    // diff) rather than silently producing a mixed-layout chain.
    let mut ck = DeltaCheckpointer::new(
        runtime(),
        DeltaConfig { chunk_size: 4096, max_chain: 8, ..DeltaConfig::default() },
    );
    let resumed = ck.resume_from(&fixture_dir().join("step-00000002")).unwrap();
    assert!(!resumed, "v3 manifests must not be adopted as chain predecessors");
    assert_eq!(ck.chain_len(), None);
}

#[test]
fn fixture_manifests_report_their_versions() {
    let text =
        std::fs::read_to_string(fixture_dir().join("step-00000002/checkpoint.json")).unwrap();
    let v = Json::parse(&text).unwrap();
    assert_eq!(v.get("manifest_version").unwrap().as_i64().unwrap(), 3);
    let _ = CheckpointManifest::from_json(&v).unwrap();
    // the v4 fixture is frozen at the last JSON-chunk-array version
    let text =
        std::fs::read_to_string(fixture_dir_v4().join("step-00000002/checkpoint.json")).unwrap();
    let v = Json::parse(&text).unwrap();
    assert_eq!(v.get("manifest_version").unwrap().as_i64().unwrap(), 4);
    let _ = CheckpointManifest::from_json(&v).unwrap();
    // the v5 fixture is frozen at the last codec-free binary-table
    // version (36-byte records, no codec tail)
    let text =
        std::fs::read_to_string(fixture_dir_v5().join("step-00000002/checkpoint.json")).unwrap();
    let v = Json::parse(&text).unwrap();
    assert_eq!(v.get("manifest_version").unwrap().as_i64().unwrap(), 5);
    let _ = CheckpointManifest::from_json(&v).unwrap();
    // the v6 fixture is exactly what the current writer emits
    let text =
        std::fs::read_to_string(fixture_dir_v6().join("step-00000002/checkpoint.json")).unwrap();
    let v = Json::parse(&text).unwrap();
    assert_eq!(
        v.get("manifest_version").unwrap().as_i64().unwrap(),
        fastpersist::checkpoint::manifest::MANIFEST_VERSION
    );
    assert_eq!(fastpersist::checkpoint::manifest::MANIFEST_VERSION, 6);
    let parsed = CheckpointManifest::from_json(&v).unwrap();
    assert!(
        v.get("delta").unwrap().opt("chunk_table").is_some(),
        "v6 fixtures must carry the binary chunk table"
    );
    assert!(v.get("delta").unwrap().opt("chunks").is_none());
    let _ = parsed;
}

// ------------------------------------------------------- corruption fuzz

/// Recursively copy a fixture chain so every corruption case gets its
/// own path — the parsed-manifest LRU is keyed by (path, mtime, length)
/// and a fresh copy can never be served a stale parse.
fn stage_chain(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap().flatten() {
        let p = e.path();
        let t = dst.join(e.file_name());
        if p.is_dir() {
            stage_chain(&p, &t);
        } else {
            std::fs::copy(&p, &t).unwrap();
        }
    }
}

/// Corrupt `rel` (a file inside the chain at `src`) with deterministic
/// truncations and scattered single-byte flips. After every corruption
/// the checkpoint at `step` must fail closed — a typed, renderable
/// error — or load the exact expected content (a flip in dead bytes is
/// benign). It must never panic and never load garbage.
fn fuzz_file_fails_closed(src: &Path, rel: &str, step: &str, expected: &TensorStore, tag: &str) {
    let rt = runtime();
    let root = scratch_dir(&format!("format-fuzz-{tag}")).unwrap();
    let original = std::fs::read(src.join(rel)).unwrap();
    let n = original.len();
    assert!(n > 8, "{tag}: fixture file {rel} is implausibly small");
    let mut cases: Vec<(String, Vec<u8>)> = Vec::new();
    for cut in [0, 1, n / 4, n / 2, n - 1] {
        cases.push((format!("truncate-{cut}"), original[..cut].to_vec()));
    }
    // nightly CI sets FASTPERSIST_FUZZ_FULL=1 for a denser sweep
    let budget: usize =
        if std::env::var("FASTPERSIST_FUZZ_FULL").is_ok_and(|v| v == "1") { 257 } else { 29 };
    let flips = budget.min(n);
    for i in 0..flips {
        let pos = i * n / flips;
        let mut m = original.clone();
        // alternate a low-bit flip (digit → neighboring digit) and a
        // case/whitespace flip so both numeric and structural bytes of
        // the format get hit
        m[pos] ^= if i % 2 == 0 { 0x01 } else { 0x20 };
        cases.push((format!("flip-{pos}"), m));
    }
    for (ctx, bytes) in cases {
        let chain = root.join(&ctx);
        stage_chain(src, &chain);
        std::fs::write(chain.join(rel), &bytes).unwrap();
        match load_checkpoint(&chain.join(step), &rt) {
            Ok((loaded, _, _)) => assert!(
                loaded.content_eq(expected),
                "{tag}/{ctx}: a corrupted {rel} must never load garbage"
            ),
            Err(e) => {
                let rendered = e.to_string();
                assert!(!rendered.is_empty(), "{tag}/{ctx}: error must render");
            }
        }
        let _ = std::fs::remove_dir_all(&chain);
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn corrupted_v3_manifest_fails_closed() {
    fuzz_file_fails_closed(
        &fixture_dir(),
        "step-00000002/checkpoint.json",
        "step-00000002",
        &expected_store(true),
        "v3-manifest",
    );
}

#[test]
fn corrupted_v4_manifest_fails_closed() {
    fuzz_file_fails_closed(
        &fixture_dir_v4(),
        "step-00000002/checkpoint.json",
        "step-00000002",
        &expected_store(true),
        "v4-manifest",
    );
}

#[test]
fn corrupted_v4_segment_fails_closed() {
    // corrupt the base's segment store and reload both the base itself
    // and the delta link that inherits chunks from it
    let src = fixture_dir_v4();
    let seg = std::fs::read_dir(src.join("step-00000001"))
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "fpseg"))
        .expect("v4 fixture has a segment file");
    let rel = format!("step-00000001/{}", seg.file_name().unwrap().to_str().unwrap());
    fuzz_file_fails_closed(&src, &rel, "step-00000001", &expected_store(false), "v4-seg-base");
    fuzz_file_fails_closed(&src, &rel, "step-00000002", &expected_store(true), "v4-seg-delta");
}

#[test]
fn corrupted_v5_manifest_fails_closed() {
    // the checkpoint.json is dominated by the hex chunk table, so the
    // scattered flips land throughout the binary records: corrupted
    // hashes, lengths, string-table indices, segment offsets, the
    // digest fields, and the hex encoding itself must all be caught
    fuzz_file_fails_closed(
        &fixture_dir_v5(),
        "step-00000002/checkpoint.json",
        "step-00000002",
        &expected_store(true),
        "v5-manifest",
    );
}

#[test]
fn corrupted_v5_segment_fails_closed() {
    let src = fixture_dir_v5();
    let seg = std::fs::read_dir(src.join("step-00000001"))
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "fpseg"))
        .expect("v5 fixture has a segment file");
    let rel = format!("step-00000001/{}", seg.file_name().unwrap().to_str().unwrap());
    fuzz_file_fails_closed(&src, &rel, "step-00000001", &expected_store(false), "v5-seg-base");
    fuzz_file_fails_closed(&src, &rel, "step-00000002", &expected_store(true), "v5-seg-delta");
}

#[test]
fn corrupted_v6_manifest_fails_closed() {
    // v6 hex-table flips additionally land in the codec tail of each
    // record: codec ids, pad bytes, encoded lengths, and the qdelta
    // base-reference sentinels — all must be caught (the table digest
    // first, the per-field codec validation behind it), never panic
    fuzz_file_fails_closed(
        &fixture_dir_v6(),
        "step-00000002/checkpoint.json",
        "step-00000002",
        &expected_store(true),
        "v6-manifest",
    );
}

#[test]
fn corrupted_v6_segment_fails_closed() {
    // v6 segments hold lz4 streams, so flips corrupt *encoded* bytes:
    // either the decoder's own fail-closed checks trip or the decoded
    // bytes miss the raw chunk hash — garbage must never load
    let src = fixture_dir_v6();
    let seg = std::fs::read_dir(src.join("step-00000001"))
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "fpseg"))
        .expect("v6 fixture has a segment file");
    let rel = format!("step-00000001/{}", seg.file_name().unwrap().to_str().unwrap());
    fuzz_file_fails_closed(&src, &rel, "step-00000001", &expected_store(false), "v6-seg-base");
    fuzz_file_fails_closed(&src, &rel, "step-00000002", &expected_store(true), "v6-seg-delta");
}

#[test]
fn v2_manifest_reads_and_fuzzes_closed() {
    // synthesize a v2 chain: a full (partitioned) checkpoint whose
    // manifest is re-stamped v2, the oldest version this build reads
    let root = scratch_dir("format-v2").unwrap();
    let rt = runtime();
    let engine = CheckpointEngine::with_runtime(Arc::clone(&rt), WriterStrategy::Rank0);
    let dir = root.join("step-00000001");
    let store = expected_store(false);
    let mut extra = std::collections::BTreeMap::new();
    extra.insert("step".to_string(), Json::Int(1));
    engine.write_single(&store, extra, &dir).unwrap();
    let mpath = dir.join("checkpoint.json");
    let parsed = Json::parse(&std::fs::read_to_string(&mpath).unwrap()).unwrap();
    let Json::Object(mut fields) = parsed else { panic!("manifest must be a JSON object") };
    assert_eq!(
        fields["manifest_version"],
        Json::Int(fastpersist::checkpoint::manifest::MANIFEST_VERSION),
        "the writer must stamp the current version"
    );
    fields.insert("manifest_version".into(), Json::Int(2));
    // v2 predates the delta section entirely
    fields.remove("delta");
    std::fs::write(&mpath, Json::Object(fields).to_string_pretty()).unwrap();
    let (loaded, _, _) = load_checkpoint(&dir, &rt).unwrap();
    assert!(loaded.content_eq(&store), "v2 manifests must still read");
    // ... and a corrupted v2 manifest fails closed like any other
    fuzz_file_fails_closed(
        &root,
        "step-00000001/checkpoint.json",
        "step-00000001",
        &store,
        "v2-manifest",
    );
    let _ = std::fs::remove_dir_all(&root);
}

/// Fixture generator — run by hand, never in CI:
///
/// ```text
/// cargo test --test format_compat -- --ignored generate_v6_fixture
/// ```
///
/// Writes the deterministic two-checkpoint chain of [`expected_store`]
/// into `rust/tests/fixtures/v6/` with the *current* (v6) writer under
/// the `lz4` codec. The frozen v3/v4/v5 fixtures come from older
/// writers; rebuild them only via the committed `gen_v4_fixture.py` /
/// `gen_v5_fixture.py` scripts (the current writer no longer emits
/// those versions). `gen_v6_fixture.py` is the toolchain-free mirror
/// of this test and self-verifies what it wrote.
#[test]
#[ignore = "regenerates the committed v6 fixture"]
fn generate_v6_fixture() {
    let dir = fixture_dir_v6();
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut ck = DeltaCheckpointer::new(
        runtime(),
        DeltaConfig {
            chunk_size: 4096,
            max_chain: 8,
            codec: CodecKind::Lz4,
            ..DeltaConfig::default()
        },
    );
    let mut extra = std::collections::BTreeMap::new();
    extra.insert("step".to_string(), Json::Int(1));
    let out = ck.write(&expected_store(false), extra, &dir.join("step-00000001")).unwrap();
    assert!(out.is_base);
    let mut extra = std::collections::BTreeMap::new();
    extra.insert("step".to_string(), Json::Int(2));
    let out = ck.write(&expected_store(true), extra, &dir.join("step-00000002")).unwrap();
    assert!(!out.is_base, "fixture step 2 must be a delta link");
}
