//! Integration: the restore-at-scale serve layer under concurrency.
//!
//! * Sixteen tenants restoring different steps (full-snapshot and
//!   delta-chain) through ONE runtime and ONE service must land
//!   bit-identical results, cold and warm.
//! * A restore racing segment GC must either serve pre-prune bytes or
//!   fail cleanly — never return a torn mix (enforced structurally by
//!   per-chunk hash + stream digest verification; this test hammers the
//!   race to prove it holds in practice).
//! * An evicted-then-refetched segment must still hash-verify: cache
//!   pressure may change *where* bytes come from, never *what* they
//!   are. Runs under the seeded property framework
//!   (`FASTPERSIST_PROP_SEED` pins CI).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use fastpersist::checkpoint::delta::{prune_chain, DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::serve::{RestoreService, ServeConfig};
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::{ClusterSpec, Parallelism, Topology};
use fastpersist::io::device::DeviceMap;
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::io::runtime::IoRuntime;
use fastpersist::prop::forall;
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;
use fastpersist::prop_assert;

fn runtime() -> Arc<IoRuntime> {
    IoRuntime::shared(IoConfig::fastpersist().microbench())
}

fn payload_store(seed: u64, nbytes: usize) -> TensorStore {
    let mut data = vec![0u8; nbytes];
    Rng::new(seed).fill_bytes(&mut data);
    let mut s = TensorStore::new();
    s.push(Tensor::new("payload", DType::U8, vec![nbytes], data).unwrap()).unwrap();
    s
}

/// Flip a contiguous span of the payload — the dirty-chunk generator
/// between delta steps.
fn mutate(s: &TensorStore, frac: f64, tag: u64) -> TensorStore {
    let mut data = s.get("payload").unwrap().data.to_vec();
    let span = ((data.len() as f64 * frac) as usize).max(1);
    let start = (tag as usize * 8191) % data.len().saturating_sub(span).max(1);
    for (i, b) in data[start..(start + span).min(data.len())].iter_mut().enumerate() {
        *b ^= (tag as u8).wrapping_add(i as u8) | 1;
    }
    let mut out = TensorStore::new();
    out.push(Tensor::new("payload", DType::U8, vec![data.len()], data).unwrap()).unwrap();
    out
}

/// Base + `n - 1` delta steps under `parent`; returns each step's dir
/// and expected state.
fn write_delta_chain(
    parent: &Path,
    rt: &Arc<IoRuntime>,
    n: usize,
    nbytes: usize,
    segment_bytes: u64,
) -> (Vec<PathBuf>, Vec<TensorStore>) {
    let mut ck = DeltaCheckpointer::new(
        Arc::clone(rt),
        DeltaConfig { chunk_size: 4096, max_chain: 32, segment_bytes, ..DeltaConfig::default() },
    );
    let mut dirs = Vec::new();
    let mut states = Vec::new();
    let mut s = payload_store(11, nbytes);
    for step in 0..n {
        if step > 0 {
            s = mutate(&s, 0.15, step as u64);
        }
        let dir = parent.join(format!("step-{:08}", step + 1));
        let mut extra = BTreeMap::new();
        extra.insert("step".to_string(), Json::Int((step + 1) as i64));
        ck.write(&s, extra, &dir).unwrap();
        dirs.push(dir);
        states.push(s.clone());
    }
    (dirs, states)
}

/// One full-snapshot (partitioned) checkpoint — the non-delta restore
/// shape, exercising the scheduler's non-cacheable path.
fn write_full(dir: &Path, seed: u64, dp: usize) -> TensorStore {
    let store = payload_store(seed, 120_000);
    let topo = Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(dp, 1, 1)).unwrap();
    CheckpointEngine::fastpersist(WriterStrategy::AllReplicas)
        .write(&store, BTreeMap::new(), dir, &topo.dp_group(0))
        .unwrap();
    store
}

#[test]
fn sixteen_tenants_restore_bit_identical_through_one_service() {
    let base = scratch_dir("cr-16tenants").unwrap();
    let rt = runtime();
    let (mut dirs, mut states) = write_delta_chain(&base.join("chain"), &rt, 6, 96 * 1024, 16 << 10);
    // mix a full-snapshot checkpoint into the pool
    let full_dir = base.join("full").join("step-00000001");
    states.push(write_full(&full_dir, 5, 2));
    dirs.push(full_dir);

    let svc = RestoreService::new(
        Arc::clone(&rt),
        ServeConfig { admit_after: 1, ..ServeConfig::with_cache(64 << 20) },
    );
    std::thread::scope(|scope| {
        for t in 0..16 {
            let svc = Arc::clone(&svc);
            let dirs = &dirs;
            let states = &states;
            scope.spawn(move || {
                let session = svc.session(format!("tenant-{t}"));
                // two passes: cold fills the cache, warm hits it — both
                // must be bit-identical to the written state
                for pass in 0..2 {
                    let i = (t + pass) % dirs.len();
                    let got = session.restore(&dirs[i]).unwrap();
                    assert!(
                        got.store.content_eq(&states[i]),
                        "tenant {t} pass {pass}: step {i} diverged"
                    );
                }
            });
        }
    });
    let s = svc.cache_stats();
    assert!(s.hits > 0, "warm passes must hit the cache: {s:?}");
    assert!(s.bytes_held <= s.budget, "{s:?}");
    assert_eq!(
        s.entries,
        s.admitted - s.evicted - s.invalidated,
        "entry lifecycle must reconcile: {s:?}"
    );
    assert!(s.admitted <= s.misses, "admissions only follow misses: {s:?}");
    std::fs::remove_dir_all(&base).unwrap();
}

#[test]
fn restore_racing_segment_gc_is_never_torn() {
    let base = scratch_dir("cr-gcrace").unwrap();
    let rt = runtime();
    let parent = base.join("chain");
    let (dirs, states) = write_delta_chain(&parent, &rt, 8, 64 * 1024, 16 << 10);
    let svc = RestoreService::new(
        Arc::clone(&rt),
        ServeConfig { admit_after: 1, ..ServeConfig::with_cache(32 << 20) },
    );
    let devices = DeviceMap::single();
    std::thread::scope(|scope| {
        let svc_reader = Arc::clone(&svc);
        let dirs_r = &dirs;
        let states_r = &states;
        let reader = scope.spawn(move || {
            let session = svc_reader.session("racer");
            let mut ok = 0u64;
            let mut clean_errs = 0u64;
            for round in 0..6 {
                for (i, dir) in dirs_r.iter().enumerate() {
                    match session.restore(dir) {
                        // served (possibly pre-prune) bytes: must be the
                        // exact written state — hash + digest verified
                        Ok(got) => {
                            assert!(
                                got.store.content_eq(&states_r[i]),
                                "round {round}: step {i} restored torn bytes"
                            );
                            ok += 1;
                        }
                        // pruned underneath us: a clean error
                        Err(_) => clean_errs += 1,
                    }
                }
            }
            (ok, clean_errs)
        });
        // GC runs concurrently, repeatedly tightening the chain
        for keep in [6usize, 4, 2] {
            prune_chain(&parent, keep, &devices, None).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let (ok, clean_errs) = reader.join().unwrap();
        assert!(ok > 0, "some restores must succeed");
        // errors are allowed (pruned steps), successes must be exact;
        // both counters just document the race actually happened
        let _ = clean_errs;
    });
    // post-race: every kept step still restores bit-identically
    let session = svc.session("post-gc");
    for i in dirs.len() - 2..dirs.len() {
        let got = session.restore(&dirs[i]).unwrap();
        assert!(got.store.content_eq(&states[i]), "kept step {i} must survive GC");
    }
    std::fs::remove_dir_all(&base).unwrap();
}

#[test]
fn evicted_then_refetched_segments_still_hash_verify() {
    let base = scratch_dir("cr-evict").unwrap();
    let rt = runtime();
    let (dirs, states) = write_delta_chain(&base.join("chain"), &rt, 4, 64 * 1024, 16 << 10);
    forall("evicted segments refetch and verify", 8, |g| {
        // budgets small enough to force eviction across the chain's
        // segment files, large enough to admit any single one
        let budget = g.u64(24 << 10, 56 << 10);
        let svc = RestoreService::new(
            Arc::clone(&rt),
            ServeConfig { admit_after: 1, ..ServeConfig::with_cache(budget) },
        );
        let session = svc.session("evictor");
        let rounds = g.usize(2, 4);
        for round in 0..rounds {
            for k in 0..dirs.len() {
                // vary the order so different segments get evicted
                let i = if round % 2 == 0 { k } else { dirs.len() - 1 - k };
                let got = match session.restore(&dirs[i]) {
                    Ok(got) => got,
                    Err(e) => {
                        g.fail(format!("restore failed under cache pressure: {e}"));
                        return false;
                    }
                };
                prop_assert!(
                    g,
                    got.store.content_eq(&states[i]),
                    "step {i} diverged after eviction/refetch (budget {budget})"
                );
            }
            let s = svc.cache_stats();
            prop_assert!(g, s.bytes_held <= s.budget, "over budget: {s:?}");
            prop_assert!(
                g,
                s.entries == s.admitted - s.evicted - s.invalidated,
                "counters diverged: {s:?}"
            );
        }
        true
    });
    std::fs::remove_dir_all(&base).unwrap();
}
