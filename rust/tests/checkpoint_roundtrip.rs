//! Integration: checkpoint write → load roundtrips through the public
//! API, across engines, strategies, DP degrees, and store shapes.

use std::collections::BTreeMap;

use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::load::load_checkpoint;
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::{ClusterSpec, Parallelism, Topology};
use fastpersist::io::engine::{scratch_dir, EngineKind, IoConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;

fn random_store(seed: u64, ntensors: usize, max_bytes: usize) -> TensorStore {
    let mut rng = Rng::new(seed);
    let mut store = TensorStore::new();
    for i in 0..ntensors {
        let n = rng.range_usize(1, max_bytes);
        let mut data = vec![0u8; n];
        rng.fill_bytes(&mut data);
        store
            .push(Tensor::new(&format!("t{i}"), DType::U8, vec![n], data).unwrap())
            .unwrap();
    }
    store
}

fn dp_group(dp: usize) -> Vec<fastpersist::cluster::RankPlacement> {
    Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(dp, 1, 1))
        .unwrap()
        .dp_group(0)
}

#[test]
fn all_engines_and_strategies_roundtrip() {
    let dir = scratch_dir("it-roundtrip").unwrap();
    let store = random_store(1, 9, 200_000);
    let mut extra = BTreeMap::new();
    extra.insert("step".into(), Json::Int(9));
    for kind in [EngineKind::Buffered, EngineKind::DirectSingle, EngineKind::DirectDouble] {
        for strategy in [
            WriterStrategy::Rank0,
            WriterStrategy::AllReplicas,
            WriterStrategy::PerSocket,
            WriterStrategy::FixedCount(3),
        ] {
            let d = dir.join(format!("{}-{}", kind.name(), strategy.name()));
            let engine = CheckpointEngine::new(IoConfig::with_kind(kind), strategy);
            let out = engine.write(&store, extra.clone(), &d, &dp_group(8)).unwrap();
            assert_eq!(out.manifest.step, 9);
            let (loaded, header, manifest) = load_checkpoint(&d, engine.runtime()).unwrap();
            assert!(loaded.content_eq(&store), "{kind:?}/{strategy:?}");
            assert_eq!(header.extra["step"], Json::Int(9));
            assert_eq!(manifest.total_len, out.total_bytes);
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn engines_produce_identical_streams() {
    // The on-disk logical stream must be byte-identical regardless of
    // which engine or how many writers produced it (§5.1: only the disk
    // writes differ, serialization unchanged).
    let dir = scratch_dir("it-identical").unwrap();
    let store = random_store(2, 5, 100_000);
    let mut digests = Vec::new();
    for (tag, kind, dp) in [
        ("buf1", EngineKind::Buffered, 1usize),
        ("dir1", EngineKind::DirectDouble, 1),
        ("dir8", EngineKind::DirectDouble, 8),
    ] {
        let d = dir.join(tag);
        let engine = CheckpointEngine::new(IoConfig::with_kind(kind), WriterStrategy::AllReplicas);
        let out = engine.write(&store, BTreeMap::new(), &d, &dp_group(dp)).unwrap();
        digests.push(out.manifest.digest);
    }
    assert!(digests.windows(2).all(|w| w[0] == w[1]), "{digests:?}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fuzz_roundtrip_many_shapes() {
    let dir = scratch_dir("it-fuzz").unwrap();
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed * 7 + 1);
        let store = random_store(seed, rng.range_usize(0, 6), 50_000);
        let dp = 1 << rng.range_usize(0, 3);
        let kind = *rng.choose(&[EngineKind::Buffered, EngineKind::DirectSingle,
            EngineKind::DirectDouble]);
        let d = dir.join(format!("f{seed}"));
        let engine = CheckpointEngine::new(IoConfig::with_kind(kind), WriterStrategy::AllReplicas);
        engine.write(&store, BTreeMap::new(), &d, &dp_group(dp)).unwrap();
        let (loaded, _, _) = load_checkpoint(&d, engine.runtime()).unwrap();
        assert!(loaded.content_eq(&store), "seed={seed}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn concurrent_checkpoints_do_not_interfere() {
    // Several checkpoints written concurrently into distinct dirs (the
    // MoE slice pattern) must all verify.
    let dir = scratch_dir("it-concurrent").unwrap();
    std::thread::scope(|scope| {
        for slice in 0..6u64 {
            let d = dir.join(format!("slice{slice}"));
            scope.spawn(move || {
                let store = random_store(slice + 100, 4, 80_000);
                let engine = CheckpointEngine::new(
                    IoConfig::fastpersist().microbench(),
                    WriterStrategy::AllReplicas,
                );
                engine.write(&store, BTreeMap::new(), &d, &dp_group(2)).unwrap();
                let (loaded, _, _) = load_checkpoint(&d, engine.runtime()).unwrap();
                assert!(loaded.content_eq(&store));
            });
        }
    });
    std::fs::remove_dir_all(&dir).unwrap();
}
