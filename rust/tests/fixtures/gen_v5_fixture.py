#!/usr/bin/env python3
"""Deterministic generator for the committed v5 fixture chain.

Mirrors `rust/tests/fixtures/v4` (same logical tensor states, same FPSG
segment packing) in the **manifest v5** encoding: the chunk table is a
hex-encoded blob of fixed-width 36-byte little-endian records plus
`sources`/`devices` string tables and a checksum64 table digest,
replacing v4's JSON `chunks` array. Byte-for-byte it reproduces what
the current Rust writer (`DeltaCheckpointer`, chunk_size 4096, single
device) emits — see `docs/FORMATS.md` for the record layout. The
Rust-side regeneration path is the ignored `generate_v5_fixture` test
in `rust/tests/format_compat.rs`; this script exists so the fixture can
be rebuilt without a Rust toolchain, and `format_compat.rs` verifies
the result reloads bit-identically.

Usage:  python3 gen_v5_fixture.py   (from this directory)
"""

import json
import os
import struct

MASK = (1 << 64) - 1
MUL = 0x9E3779B97F4A7C15
CHUNK = 4096
SEGMENT_HEADER_LEN = 4096
HEADER_PAD = 256
PREAMBLE_LEN = 16
NO_INDEX = 0xFFFFFFFF
RECORD = struct.Struct("<QQIIIQ")  # hash, len, src_idx, dev_idx, seg, off


def checksum64(data: bytes) -> int:
    """Port of serialize::format::checksum64_slice."""
    h = 0xCBF29CE484222325
    n = len(data) - len(data) % 8
    for i in range(0, n, 8):
        (word,) = struct.unpack_from("<Q", data, i)
        h = ((h ^ word) * MUL) & MASK
        h ^= h >> 29
    rem = data[n:]
    if rem:
        carry = 0
        for i, b in enumerate(rem):
            carry |= b << (8 * i)
        word = carry | (len(rem) << 56)
        h = ((h ^ word) * MUL) & MASK
        h ^= h >> 29
    return h


def combine_digests(header_digest: int, data_digest: int) -> int:
    """Port of serialize::format::combine_digests."""
    h = 0x84222325_CBF29CE4
    h = ((h ^ header_digest) * MUL) & MASK
    h ^= h >> 29
    h = ((h ^ data_digest) * MUL) & MASK
    h ^= h >> 29
    return h


def expected_data(mutated: bool) -> bytes:
    nbytes = 6 * 4096 + 777
    data = bytearray((i * 131 + 7) % 256 for i in range(nbytes))
    if mutated:
        start = nbytes // 3
        n = nbytes // 10
        for i in range(start, start + n):
            data[i] ^= 0x5A
    return bytes(data)


def encode_header(data: bytes, step: int) -> bytes:
    """FormatHeader::encode — compact JSON with BTreeMap-sorted keys,
    space-padded so preamble+JSON is a HEADER_PAD multiple."""
    digest = checksum64(data)
    header = {
        "data_len": len(data),
        "digest_hi": digest >> 32,
        "digest_lo": digest & 0xFFFFFFFF,
        "extra": {"step": step},
        "tensors": [{"dtype": "u8", "name": "w", "offset": 0, "shape": [len(data)]}],
        "version": 1,
    }
    js = json.dumps(header, sort_keys=True, separators=(",", ":")).encode()
    total = PREAMBLE_LEN + len(js)
    total += -total % HEADER_PAD
    hlen = total - PREAMBLE_LEN
    out = b"FPCK" + struct.pack("<IQ", 1, hlen) + js
    return out + b" " * (total - len(out))


def grid_of(header: bytes, data: bytes):
    """Header-split chunk grid: chunk 0 = header, rest tile the data."""
    chunks = [(checksum64(header), len(header))]
    for off in range(0, len(data), CHUNK):
        piece = data[off : off + CHUNK]
        chunks.append((checksum64(piece), len(piece)))
    return chunks


def encode_segment_header(index: int, chunks: int, payload_len: int) -> bytes:
    out = b"FPSG" + struct.pack("<III", 1, index, chunks) + struct.pack("<Q", payload_len)
    return out + b"\0" * (SEGMENT_HEADER_LEN - len(out))


def encode_chunk_table(entries):
    """The v5 binary chunk table: one RECORD per chunk plus the
    first-appearance-interned string tables it indexes into.

    `entries` is a list of (hash, len, source|None, device|None,
    seg|None, off). Returns (hex_blob, digest, sources, devices)."""
    sources, devices, records = [], [], bytearray()

    def intern(table, s):
        if s is None:
            return NO_INDEX
        if s not in table:
            table.append(s)
        return table.index(s)

    for h, l, src, dev, seg, off in entries:
        records += RECORD.pack(
            h,
            l,
            intern(sources, src),
            intern(devices, dev),
            NO_INDEX if seg is None else seg,
            0 if seg is None else off,
        )
    return bytes(records).hex(), checksum64(bytes(records)), sources, devices


def write_checkpoint(dirname: str, step: int, mutated: bool, prev):
    """Write one checkpoint the way DeltaCheckpointer::write does on a
    single device: dirty chunks packed into one segment (data chunks in
    stream order, header chunk last), fully resolved v5 manifest.
    Returns this checkpoint's resolved table for the next diff."""
    data = expected_data(mutated)
    header = encode_header(data, step)
    stream = header + data
    grid = grid_of(header, data)
    digest = combine_digests(checksum64(header), checksum64(data))

    offsets = []
    off = 0
    for _, length in grid:
        offsets.append(off)
        off += length
    dirty = [
        i
        for i, (h, l) in enumerate(grid)
        if prev is None or prev[i][:2] != (h, l)
    ]
    # segment packing order: data chunks first, header chunk last
    order = [i for i in dirty if i != 0] + [i for i in dirty if i == 0]
    seg_ref, payload, ranges = {}, 0, []
    for i in order:
        seg_ref[i] = SEGMENT_HEADER_LEN + payload
        s, e = offsets[i], offsets[i] + grid[i][1]
        if ranges and ranges[-1][1] == s:
            ranges[-1] = (ranges[-1][0], e)
        else:
            ranges.append((s, e))
        payload += grid[i][1]

    os.makedirs(dirname, exist_ok=True)
    with open(os.path.join(dirname, "seg-000000.fpseg"), "wb") as f:
        f.write(encode_segment_header(0, len(order), payload))
        for s, e in ranges:
            f.write(stream[s:e])

    name = os.path.basename(dirname)
    resolved, entries = [], []
    for i, (h, l) in enumerate(grid):
        if i in seg_ref:
            # local chunk: no source (this dir), packed into segment 0
            entries.append((h, l, None, None, 0, seg_ref[i]))
            resolved.append((h, l, name, 0, seg_ref[i]))
        else:
            _, _, src, seg, soff = prev[i]
            entries.append((h, l, src, None, seg, soff))
            resolved.append((h, l, src, seg, soff))
    table_hex, table_digest, sources, devices = encode_chunk_table(entries)
    delta = {
        "chain_len": 0 if prev is None else 1,
        "chunk_size": CHUNK,
        "chunk_count": len(entries),
        "table_digest_hi": table_digest >> 32,
        "table_digest_lo": table_digest & 0xFFFFFFFF,
        "chunk_table": table_hex,
        "header_len": len(header),
    }
    if sources:
        delta["sources"] = sources
    if devices:
        delta["devices"] = devices
    if prev is not None:
        delta["base"] = "step-00000001"
    manifest = {
        "manifest_version": 5,
        "total_len": len(stream),
        "digest_hi": digest >> 32,
        "digest_lo": digest & 0xFFFFFFFF,
        "step": step,
        "partitions": [],
        "delta": delta,
    }
    with open(os.path.join(dirname, "checkpoint.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return resolved


def main():
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "v5")
    base = write_checkpoint(os.path.join(root, "step-00000001"), 1, False, None)
    write_checkpoint(os.path.join(root, "step-00000002"), 2, True, base)
    print(f"wrote v5 fixture under {root}")


if __name__ == "__main__":
    main()
