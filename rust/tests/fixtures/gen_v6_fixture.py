#!/usr/bin/env python3
"""Deterministic generator for the committed v6 fixture chain.

Mirrors `rust/tests/fixtures/v5` (same logical tensor states, same FPSG
segment packing) in the **manifest v6** encoding: the binary chunk
record widens from 36 to 76 bytes to carry the codec stage — codec id,
encoded length, and the quantized-delta base reference — and the chain
is written with the `lz4` codec, so dirty chunks are stored as in-repo
LZ77 block streams (the compressor below is a line-for-line port of
`checkpoint::codec::lz4_compress`, greedy hash-chain matching with
4-bit length nibbles and 16-bit offsets). Chunks whose encoding does
not shrink them store raw (the benefit gate), exactly like the Rust
writer; `hash` and `len` always describe the chunk's *raw* bytes. See
`docs/FORMATS.md` for the record layout.

The Rust-side regeneration path is the ignored `generate_v6_fixture`
test in `rust/tests/format_compat.rs`; this script exists so the
fixture can be rebuilt without a Rust toolchain, and `format_compat.rs`
verifies the result reloads bit-identically. The script also re-decodes
everything it wrote (segments -> lz4 -> stream digest) before exiting,
so a generation bug fails here, not in CI.

Usage:  python3 gen_v6_fixture.py   (from this directory)
"""

import json
import os
import struct

MASK = (1 << 64) - 1
MUL = 0x9E3779B97F4A7C15
CHUNK = 4096
SEGMENT_HEADER_LEN = 4096
HEADER_PAD = 256
PREAMBLE_LEN = 16
NO_INDEX = 0xFFFFFFFF
CODEC_NONE = 0
CODEC_LZ4 = 1
# v6 record: the 36-byte v5 layout + codec id, 3 pad bytes, encoded
# length, and the qdelta base reference (sentinel here: lz4 has no base)
RECORD_V6 = struct.Struct("<QQIIIQB3xQIIIQQ")


def checksum64(data: bytes) -> int:
    """Port of serialize::format::checksum64_slice."""
    h = 0xCBF29CE484222325
    n = len(data) - len(data) % 8
    for i in range(0, n, 8):
        (word,) = struct.unpack_from("<Q", data, i)
        h = ((h ^ word) * MUL) & MASK
        h ^= h >> 29
    rem = data[n:]
    if rem:
        carry = 0
        for i, b in enumerate(rem):
            carry |= b << (8 * i)
        word = carry | (len(rem) << 56)
        h = ((h ^ word) * MUL) & MASK
        h ^= h >> 29
    return h


def combine_digests(header_digest: int, data_digest: int) -> int:
    """Port of serialize::format::combine_digests."""
    h = 0x84222325_CBF29CE4
    h = ((h ^ header_digest) * MUL) & MASK
    h ^= h >> 29
    h = ((h ^ data_digest) * MUL) & MASK
    h ^= h >> 29
    return h


# ------------------------------------------------------------------ lz4
# Port of checkpoint::codec::lz4_compress / lz4_decompress_into.

LZ_HASH_BITS = 13
LZ_MIN_MATCH = 4
LZ_MAX_OFFSET = 0xFFFF


def _push_run(out: bytearray, n: int):
    while n >= 255:
        out.append(255)
        n -= 255
    out.append(n)


def _emit_sequence(out: bytearray, literals: bytes, m):
    lit_code = min(len(literals), 15)
    match_code = 0 if m is None else min(m[1] - (LZ_MIN_MATCH - 1), 15)
    out.append((lit_code << 4) | match_code)
    if len(literals) >= 15:
        _push_run(out, len(literals) - 15)
    out += literals
    if m is not None:
        offset, length = m
        out += offset.to_bytes(2, "little")
        if length - (LZ_MIN_MATCH - 1) >= 15:
            _push_run(out, length - (LZ_MIN_MATCH - 1) - 15)


def lz4_compress(src: bytes) -> bytes:
    out = bytearray()
    table = [0] * (1 << LZ_HASH_BITS)
    n = len(src)

    def word(p):
        return int.from_bytes(src[p : p + 4], "little")

    i = anchor = 0
    while i + LZ_MIN_MATCH <= n:
        w = word(i)
        h = ((w * 2654435761) & 0xFFFFFFFF) >> (32 - LZ_HASH_BITS)
        cand = table[h]
        table[h] = i + 1
        if cand > 0:
            c = cand - 1
            if i - c <= LZ_MAX_OFFSET and word(c) == w:
                length = LZ_MIN_MATCH
                while i + length < n and src[c + length] == src[i + length]:
                    length += 1
                _emit_sequence(out, src[anchor:i], (i - c, length))
                i += length
                anchor = i
                continue
        i += 1
    _emit_sequence(out, src[anchor:], None)
    return bytes(out)


def lz4_decompress(src: bytes, raw_len: int) -> bytes:
    """Reference decoder for the self-check at the end of generation."""
    dest = bytearray()
    i = 0
    while True:
        token = src[i]
        i += 1
        lit = token >> 4
        if lit == 15:
            while True:
                b = src[i]
                i += 1
                lit += b
                if b < 255:
                    break
        dest += src[i : i + lit]
        i += lit
        mcode = token & 0x0F
        if mcode == 0:
            assert i == len(src) and len(dest) == raw_len, "bad terminal"
            return bytes(dest)
        offset = int.from_bytes(src[i : i + 2], "little")
        i += 2
        mlen = mcode + (LZ_MIN_MATCH - 1)
        if mcode == 15:
            while True:
                b = src[i]
                i += 1
                mlen += b
                if b < 255:
                    break
        assert 0 < offset <= len(dest), "bad offset"
        for _ in range(mlen):
            dest.append(dest[-offset])


# ------------------------------------------------------- fixture content


def expected_data(mutated: bool) -> bytes:
    nbytes = 6 * 4096 + 777
    data = bytearray((i * 131 + 7) % 256 for i in range(nbytes))
    if mutated:
        start = nbytes // 3
        n = nbytes // 10
        for i in range(start, start + n):
            data[i] ^= 0x5A
    return bytes(data)


def encode_header(data: bytes, step: int) -> bytes:
    """FormatHeader::encode — compact JSON with BTreeMap-sorted keys,
    space-padded so preamble+JSON is a HEADER_PAD multiple."""
    digest = checksum64(data)
    header = {
        "data_len": len(data),
        "digest_hi": digest >> 32,
        "digest_lo": digest & 0xFFFFFFFF,
        "extra": {"step": step},
        "tensors": [{"dtype": "u8", "name": "w", "offset": 0, "shape": [len(data)]}],
        "version": 1,
    }
    js = json.dumps(header, sort_keys=True, separators=(",", ":")).encode()
    total = PREAMBLE_LEN + len(js)
    total += -total % HEADER_PAD
    hlen = total - PREAMBLE_LEN
    out = b"FPCK" + struct.pack("<IQ", 1, hlen) + js
    return out + b" " * (total - len(out))


def grid_of(header: bytes, data: bytes):
    """Header-split chunk grid: chunk 0 = header, rest tile the data."""
    chunks = [(checksum64(header), len(header))]
    for off in range(0, len(data), CHUNK):
        piece = data[off : off + CHUNK]
        chunks.append((checksum64(piece), len(piece)))
    return chunks


def encode_segment_header(index: int, chunks: int, payload_len: int) -> bytes:
    out = b"FPSG" + struct.pack("<III", 1, index, chunks) + struct.pack("<Q", payload_len)
    return out + b"\0" * (SEGMENT_HEADER_LEN - len(out))


def encode_chunk_table(entries):
    """The v6 binary chunk table: one RECORD_V6 per chunk plus the
    first-appearance-interned string tables it indexes into.

    `entries` is a list of (hash, len, source|None, device|None, seg,
    off, codec, enc_len). The qdelta base fields are always the sentinel
    here — this fixture's codec is lz4, which never carries a base.
    Returns (hex_blob, digest, sources, devices)."""
    sources, devices, records = [], [], bytearray()

    def intern(table, s):
        if s is None:
            return NO_INDEX
        if s not in table:
            table.append(s)
        return table.index(s)

    for h, l, src, dev, seg, off, codec, enc_len in entries:
        records += RECORD_V6.pack(
            h,
            l,
            intern(sources, src),
            intern(devices, dev),
            seg,
            off,
            codec,
            enc_len,
            NO_INDEX,  # base source: none
            NO_INDEX,  # base device: none
            NO_INDEX,  # base segment: no base
            0,
            0,
        )
    return bytes(records).hex(), checksum64(bytes(records)), sources, devices


def write_checkpoint(dirname: str, step: int, mutated: bool, prev):
    """Write one checkpoint the way DeltaCheckpointer::write does on a
    single device with `codec: lz4`: dirty chunks are lz4-encoded (raw
    when encoding does not shrink — the benefit gate), packed into one
    segment in stream order with the header chunk last, and recorded in
    a fully resolved v6 manifest. Returns this checkpoint's resolved
    table for the next diff."""
    data = expected_data(mutated)
    header = encode_header(data, step)
    stream = header + data
    grid = grid_of(header, data)
    digest = combine_digests(checksum64(header), checksum64(data))

    offsets = []
    off = 0
    for _, length in grid:
        offsets.append(off)
        off += length
    dirty = [
        i
        for i, (h, l) in enumerate(grid)
        if prev is None or prev[i][:2] != (h, l)
    ]
    # codec stage: encode each dirty chunk, keep only shrinking encodings
    stored_bytes = {}
    codec_of = {}
    for i in dirty:
        raw = stream[offsets[i] : offsets[i] + grid[i][1]]
        enc = lz4_compress(raw)
        if len(enc) < len(raw):
            stored_bytes[i], codec_of[i] = enc, CODEC_LZ4
        else:
            stored_bytes[i], codec_of[i] = raw, CODEC_NONE
    # segment packing order: data chunks first, header chunk last
    order = [i for i in dirty if i != 0] + [i for i in dirty if i == 0]
    seg_ref, payload = {}, 0
    for i in order:
        seg_ref[i] = SEGMENT_HEADER_LEN + payload
        payload += len(stored_bytes[i])

    os.makedirs(dirname, exist_ok=True)
    with open(os.path.join(dirname, "seg-000000.fpseg"), "wb") as f:
        f.write(encode_segment_header(0, len(order), payload))
        for i in order:
            f.write(stored_bytes[i])

    name = os.path.basename(dirname)
    resolved, entries = [], []
    for i, (h, l) in enumerate(grid):
        if i in seg_ref:
            # dirty chunk: no source (this dir), packed into segment 0
            ck, el = codec_of[i], len(stored_bytes[i])
            entries.append((h, l, None, None, 0, seg_ref[i], ck, el))
            resolved.append((h, l, name, 0, seg_ref[i], ck, el))
        else:
            # clean chunk: inherit where (and how) the bytes are stored
            _, _, src, seg, soff, ck, el = prev[i]
            entries.append((h, l, src, None, seg, soff, ck, el))
            resolved.append((h, l, src, seg, soff, ck, el))
    table_hex, table_digest, sources, devices = encode_chunk_table(entries)
    delta = {
        "chain_len": 0 if prev is None else 1,
        "chunk_size": CHUNK,
        "chunk_count": len(entries),
        "table_digest_hi": table_digest >> 32,
        "table_digest_lo": table_digest & 0xFFFFFFFF,
        "chunk_table": table_hex,
        "header_len": len(header),
    }
    if sources:
        delta["sources"] = sources
    if devices:
        delta["devices"] = devices
    if prev is not None:
        delta["base"] = "step-00000001"
    manifest = {
        "manifest_version": 6,
        "total_len": len(stream),
        "digest_hi": digest >> 32,
        "digest_lo": digest & 0xFFFFFFFF,
        "step": step,
        "partitions": [],
        "io_backend": "sync",
        "delta": delta,
    }
    with open(os.path.join(dirname, "checkpoint.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return resolved


def verify_checkpoint(root: str, name: str, mutated: bool, lz4_expected: int):
    """Self-check: re-decode the chain member at `name` purely from the
    files on disk (manifest -> records -> segment reads -> lz4 decode)
    and assert the reassembled stream is bit-identical."""
    with open(os.path.join(root, name, "checkpoint.json")) as f:
        m = json.load(f)
    records = bytes.fromhex(m["delta"]["chunk_table"])
    want = (m["delta"]["table_digest_hi"] << 32) | m["delta"]["table_digest_lo"]
    assert checksum64(records) == want, "table digest mismatch"
    sources = m["delta"].get("sources", [])
    data = expected_data(mutated)
    header = encode_header(data, m["step"])
    stream = header + data
    out, pos, n_lz4 = bytearray(), 0, 0
    for k in range(m["delta"]["chunk_count"]):
        rec = RECORD_V6.unpack_from(records, k * RECORD_V6.size)
        h, l, src_idx, _dev, _seg, off, codec, enc_len = rec[:8]
        src = name if src_idx == NO_INDEX else sources[src_idx]
        with open(os.path.join(root, src, "seg-000000.fpseg"), "rb") as f:
            f.seek(off)
            enc = f.read(enc_len)
        raw = enc if codec == CODEC_NONE else lz4_decompress(enc, l)
        assert checksum64(raw) == h, f"chunk {k} hash mismatch"
        n_lz4 += codec == CODEC_LZ4
        out += raw
        pos += l
    assert pos == m["total_len"] and bytes(out) == stream, f"{name} diverged"
    assert n_lz4 >= lz4_expected, f"{name}: only {n_lz4} lz4 chunks"
    print(f"  {name}: {len(out)} bytes OK, {n_lz4} lz4-encoded chunks")


def main():
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "v6")
    base = write_checkpoint(os.path.join(root, "step-00000001"), 1, False, None)
    write_checkpoint(os.path.join(root, "step-00000002"), 2, True, base)
    verify_checkpoint(root, "step-00000001", False, lz4_expected=1)
    verify_checkpoint(root, "step-00000002", True, lz4_expected=1)
    print(f"wrote v6 fixture under {root}")


if __name__ == "__main__":
    main()
