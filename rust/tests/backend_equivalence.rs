//! Submission-backend equivalence: the batched ring backend and the
//! per-extent sync backend must produce **bit-identical durable
//! checkpoints** for every plan shape — full, staged depth ≥ 2, delta,
//! lazy — because the backend only changes *how* extents reach the
//! kernel, never *what* lands on disk.
//!
//! Every test compares `--io-backend sync` against `--io-backend auto`
//! (and explicit `ring` where the environment supports it): on
//! tmpfs/9p CI auto deliberately resolves to sync, so the comparison
//! degenerates to a determinism check and stays green; on a
//! ring-capable kernel it is the real cross-backend equivalence. The
//! counter test is ring-only and skips with a logged reason where the
//! probe reports unsupported — the graceful-skip contract of the
//! `--features io-uring` CI job.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::lazy::{LazyCheckpointer, LazyConfig};
use fastpersist::checkpoint::load::load_checkpoint;
use fastpersist::checkpoint::manifest::{CheckpointManifest, MANIFEST_FILE};
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::{ClusterSpec, Parallelism, Topology};
use fastpersist::io::engine::{scratch_dir, EngineKind, IoBackend, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;

fn runtime(backend: IoBackend, kind: EngineKind, queue_depth: usize) -> Arc<IoRuntime> {
    Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig { backend, queue_depth, ..IoConfig::with_kind(kind) },
        ..IoRuntimeConfig::default()
    }))
}

/// True when the explicit ring backend is usable against `dir` in this
/// environment (feature compiled in, io_uring_setup permitted, probe
/// write succeeded on the filesystem).
fn ring_usable(rt: &IoRuntime, dir: &Path) -> bool {
    rt.ring_enabled() && rt.devices().ring_capability_for(dir).is_supported()
}

fn random_store(seed: u64, ntensors: usize, max_bytes: usize) -> TensorStore {
    let mut rng = Rng::new(seed);
    let mut store = TensorStore::new();
    for i in 0..ntensors {
        let n = rng.range_usize(1, max_bytes);
        let mut data = vec![0u8; n];
        rng.fill_bytes(&mut data);
        store
            .push(Tensor::new(&format!("t{i}"), DType::U8, vec![n], data).unwrap())
            .unwrap();
    }
    store
}

fn dp_group(dp: usize) -> Vec<fastpersist::cluster::RankPlacement> {
    Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(dp, 1, 1))
        .unwrap()
        .dp_group(0)
}

fn extra(step: i64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step));
    m
}

/// Every regular file under `dir` (relative path → bytes), excluding
/// the manifest (its `io_backend` stamp legitimately differs across
/// backends — compared separately with the stamp normalized out).
fn dir_files(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().to_string();
                if rel.ends_with(MANIFEST_FILE) {
                    continue;
                }
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

/// Bit-identity of two checkpoint directories: every payload file equal
/// byte for byte, manifests equal once the backend stamp is normalized.
fn assert_checkpoints_identical(a: &Path, b: &Path, ctx: &str) {
    let fa = dir_files(a);
    let fb = dir_files(b);
    assert_eq!(
        fa.keys().collect::<Vec<_>>(),
        fb.keys().collect::<Vec<_>>(),
        "{ctx}: file sets differ"
    );
    for (name, bytes) in &fa {
        assert_eq!(bytes, &fb[name], "{ctx}: payload file {name} differs");
    }
    let mut ma = CheckpointManifest::load(a).unwrap();
    let mut mb = CheckpointManifest::load(b).unwrap();
    ma.io_backend = None;
    mb.io_backend = None;
    assert_eq!(ma, mb, "{ctx}: manifests differ beyond the backend stamp");
}

#[test]
fn full_checkpoints_bit_identical_across_backends_random_shapes() {
    let base = scratch_dir("be-full").unwrap();
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed * 131 + 7);
        let store = random_store(seed, rng.range_usize(1, 6), 150_000);
        // staged depth >= 2 is the interesting shape (batches form);
        // depth 1 is the degenerate single-buffered plan
        let qd = *rng.choose(&[1usize, 2, 4]);
        let kind = *rng.choose(&[EngineKind::DirectSingle, EngineKind::DirectDouble]);
        let dp = 1 << rng.range_usize(0, 2);

        let mut dirs = Vec::new();
        let mut backends = vec![(IoBackend::Sync, "sync"), (IoBackend::Auto, "auto")];
        let probe_rt = runtime(IoBackend::Ring, kind, qd);
        if ring_usable(&probe_rt, &base) {
            backends.push((IoBackend::Ring, "ring"));
        }
        for (backend, tag) in &backends {
            let d = base.join(format!("s{seed}-{tag}"));
            let rt = runtime(*backend, kind, qd);
            let engine = CheckpointEngine::with_runtime(rt, WriterStrategy::AllReplicas);
            let out = engine.write(&store, extra(seed as i64), &d, &dp_group(dp)).unwrap();
            if matches!(*backend, IoBackend::Sync) {
                assert_eq!(
                    out.batched_submissions(),
                    0,
                    "sync backend must never count ring submissions"
                );
            }
            // whatever drained it, the checkpoint must load bit-identically
            let (loaded, _, _) = load_checkpoint(&d, engine.runtime()).unwrap();
            assert!(loaded.content_eq(&store), "seed {seed} via {tag}");
            dirs.push(d);
        }
        for other in &dirs[1..] {
            assert_checkpoints_identical(&dirs[0], other, &format!("seed {seed} qd {qd}"));
        }
    }
    std::fs::remove_dir_all(&base).unwrap();
}

#[test]
fn delta_chains_bit_identical_across_backends() {
    let base = scratch_dir("be-delta").unwrap();
    let chunk = 4096u64;
    let mut backends = vec![(IoBackend::Sync, "sync"), (IoBackend::Auto, "auto")];
    if ring_usable(&runtime(IoBackend::Ring, EngineKind::DirectDouble, 4), &base) {
        backends.push((IoBackend::Ring, "ring"));
    }

    // identical mutation series per backend: base + 3 deltas
    let mut roots: Vec<PathBuf> = Vec::new();
    for (backend, tag) in &backends {
        let root = base.join(tag);
        let rt = runtime(*backend, EngineKind::DirectDouble, 4);
        let mut writer = DeltaCheckpointer::new(
            Arc::clone(&rt),
            DeltaConfig { chunk_size: chunk, max_chain: 8, ..DeltaConfig::default() },
        );
        let mut store = random_store(99, 1, 40 * chunk as usize);
        for step in 1..=4i64 {
            writer.write(&store, extra(step), &root.join(format!("step-{step:08}"))).unwrap();
            // deterministic dirtying for the next delta
            let data = {
                let t = store.get("t0").unwrap();
                let mut d = t.data.as_slice().to_vec();
                let start = d.len() / 3;
                let end = start + d.len() / 8;
                for b in &mut d[start..end] {
                    *b ^= step as u8 | 1;
                }
                d
            };
            store.update("t0", data).unwrap();
        }
        // the chain must load from its newest generation on every backend
        let (loaded, _, manifest) =
            load_checkpoint(&root.join(format!("step-{:08}", 4)), &rt).unwrap();
        assert_eq!(manifest.step, 4);
        assert!(loaded.total_bytes() > 0);
        roots.push(root);
    }
    for step in 1..=4i64 {
        let name = format!("step-{step:08}");
        for other in &roots[1..] {
            assert_checkpoints_identical(
                &roots[0].join(&name),
                &other.join(&name),
                &format!("delta {name}"),
            );
        }
    }
    std::fs::remove_dir_all(&base).unwrap();
}

#[test]
fn lazy_generations_bit_identical_across_backends() {
    let base = scratch_dir("be-lazy").unwrap();
    let chunk = 4096u64;
    let mut backends = vec![(IoBackend::Sync, "sync"), (IoBackend::Auto, "auto")];
    if ring_usable(&runtime(IoBackend::Ring, EngineKind::DirectDouble, 2), &base) {
        backends.push((IoBackend::Ring, "ring"));
    }
    let mut roots: Vec<PathBuf> = Vec::new();
    for (backend, tag) in &backends {
        let root = base.join(tag);
        let rt = runtime(*backend, EngineKind::DirectDouble, 2);
        let writer = DeltaCheckpointer::new(
            Arc::clone(&rt),
            DeltaConfig { chunk_size: chunk, max_chain: 8, ..DeltaConfig::default() },
        );
        let mut lazy = LazyCheckpointer::delta(
            writer,
            LazyConfig { staging_bytes: 8 << 20, buf_size: 1 << 20, max_generations: 2 },
        );
        let mut store = random_store(7, 1, 20 * chunk as usize);
        for step in 1..=3i64 {
            lazy.capture(&store, extra(step), root.join(format!("step-{step:08}"))).unwrap();
            let data = {
                let t = store.get("t0").unwrap();
                let mut d = t.data.as_slice().to_vec();
                for b in &mut d[..d.len() / 5] {
                    *b = b.wrapping_add(step as u8);
                }
                d
            };
            store.update("t0", data).unwrap();
        }
        lazy.wait_all().unwrap();
        roots.push(root);
    }
    for step in 1..=3i64 {
        let name = format!("step-{step:08}");
        for other in &roots[1..] {
            assert_checkpoints_identical(
                &roots[0].join(&name),
                &other.join(&name),
                &format!("lazy {name}"),
            );
        }
    }
    std::fs::remove_dir_all(&base).unwrap();
}

#[test]
fn ring_batches_multiple_extents_per_submission_syscall() {
    let base = scratch_dir("be-counters").unwrap();
    let qd = 4usize;
    let rt = runtime(IoBackend::Ring, EngineKind::DirectDouble, qd);
    if !ring_usable(&rt, &base) {
        eprintln!("skipping ring counter test: ring backend unavailable in this environment");
        std::fs::remove_dir_all(&base).unwrap();
        return;
    }
    // small staging buffers against a large payload → many extents per
    // partition, so queue-depth batches actually form
    let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig {
            backend: IoBackend::Ring,
            queue_depth: qd,
            io_buf_size: 64 * 1024,
            ..IoConfig::with_kind(EngineKind::DirectDouble)
        },
        ..IoRuntimeConfig::default()
    }));
    assert_eq!(rt.submit_backend_name(&base), "ring");
    // fixed 1 MiB payload >> 64 KiB staging buffers: ~16 extents per
    // partition, so full queue-depth batches are guaranteed to form
    let mut data = vec![0u8; 1 << 20];
    Rng::new(3).fill_bytes(&mut data);
    let mut store = TensorStore::new();
    store.push(Tensor::new("w", DType::U8, vec![data.len()], data).unwrap()).unwrap();
    let engine = CheckpointEngine::with_runtime(Arc::clone(&rt), WriterStrategy::Rank0);
    let dir = base.join("ck");
    let out = engine.write(&store, extra(1), &dir, &dp_group(1)).unwrap();
    let subs = out.batched_submissions();
    let reaped = out.completions_reaped();
    assert!(subs >= 1, "ring path must count its submission syscalls");
    assert!(
        out.sqes_per_submit_max() >= 2,
        "queue_depth {qd} must put >= 2 sqes into one submission (got max {})",
        out.sqes_per_submit_max()
    );
    // one submission syscall per queue-depth batch: on average every
    // syscall must carry >= 2 completions (extents + chained flush)
    assert!(
        reaped >= 2 * subs,
        "expected >= 2 extents per submission syscall, got {reaped} completions \
         over {subs} submissions"
    );
    // the manifest records which path produced the checkpoint
    let manifest = CheckpointManifest::load(&dir).unwrap();
    assert_eq!(manifest.io_backend.as_deref(), Some("ring"));
    std::fs::remove_dir_all(&base).unwrap();
}

#[test]
fn auto_backend_stamps_resolved_path_into_manifest() {
    // Whatever `auto` resolves to in this environment, the manifest
    // must say so — and on tmpfs/9p CI that is deliberately "sync".
    let base = scratch_dir("be-stamp").unwrap();
    let rt = runtime(IoBackend::Auto, EngineKind::DirectDouble, 2);
    let expected = rt.submit_backend_name(&base);
    let engine = CheckpointEngine::with_runtime(Arc::clone(&rt), WriterStrategy::AllReplicas);
    let dir = base.join("ck");
    let out = engine.write(&random_store(11, 2, 50_000), extra(2), &dir, &dp_group(2)).unwrap();
    let manifest = CheckpointManifest::load(&dir).unwrap();
    assert_eq!(manifest.io_backend.as_deref(), Some(expected));
    if expected == "sync" {
        assert_eq!(out.batched_submissions(), 0);
        assert_eq!(out.completions_reaped(), 0);
    }
    std::fs::remove_dir_all(&base).unwrap();
}
