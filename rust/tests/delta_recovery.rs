//! Crash-recovery drill for incremental (delta) checkpointing.
//!
//! The delta commit protocol: dirty chunks first, manifest last (atomic
//! rename). So a crash mid-flush leaves a directory *without* a
//! manifest, and recovery must (a) skip it, falling back to the newest
//! complete checkpoint of the chain, and (b) let a restarted writer
//! resume the chain from that checkpoint — all bit-identically.

use std::collections::BTreeMap;
use std::sync::Arc;

use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::load::load_checkpoint;
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::io::fault::{FaultKind, FaultPlan, FaultSite};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::training::looper::Trainer;
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;
use fastpersist::Error;

const CS: u64 = 4096;

fn runtime() -> Arc<IoRuntime> {
    runtime_with(None)
}

fn runtime_with(fault: Option<FaultPlan>) -> Arc<IoRuntime> {
    Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig { fault, ..IoConfig::fastpersist().microbench() },
        ..IoRuntimeConfig::default()
    }))
}

fn store(seed: u64, nbytes: usize) -> TensorStore {
    let mut rng = Rng::new(seed);
    let mut s = TensorStore::new();
    let mut data = vec![0u8; nbytes];
    rng.fill_bytes(&mut data);
    s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
    s
}

fn mutate(s: &mut TensorStore, frac: f64, tag: u8) {
    let t = s.get("w").unwrap();
    let mut data = t.data.as_slice().to_vec();
    let n = (data.len() as f64 * frac) as usize;
    let start = data.len() / 4;
    for b in &mut data[start..start + n] {
        *b ^= tag | 1;
    }
    s.update("w", data).unwrap();
}

fn extra(step: i64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step));
    m
}

#[test]
fn interrupted_delta_flush_falls_back_to_last_complete_chain() {
    let dir = scratch_dir("delta-crash").unwrap();
    // crash mid-flush of step 3: the injected fault fires at the third
    // manifest publish (0-based boundary 2) — chunks hit storage, the
    // atomic rename that would commit them never happens
    let fault = FaultPlan::fire_at(FaultKind::Abort, FaultSite::Publish, 2);
    let rt = runtime_with(Some(fault.clone()));
    let mut ck = DeltaCheckpointer::new(Arc::clone(&rt), DeltaConfig {
        chunk_size: CS,
        max_chain: 8,
        ..DeltaConfig::default()
    });

    // healthy chain: base + delta
    let mut s = store(42, 30 * CS as usize);
    ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
    mutate(&mut s, 0.04, 1);
    ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
    let state_at_2 = s.snapshot();

    mutate(&mut s, 0.04, 2);
    let step3 = dir.join("step-00000003");
    let err = ck.write(&s, extra(3), &step3).unwrap_err();
    assert!(matches!(err, Error::FaultTripped(_)), "got {err}");
    assert!(fault.tripped() && fault.halted());
    assert!(
        std::fs::read_dir(&step3).unwrap().flatten().count() > 0,
        "crash drill needs flushed chunks on disk"
    );
    // "restart": the halted runtime comes back for the recovery phase
    fault.heal();

    // recovery: the incomplete directory is invisible to discovery and
    // unloadable directly
    let latest = Trainer::latest_checkpoint(&dir).unwrap().unwrap();
    assert!(latest.ends_with("step-00000002"), "latest = {latest:?}");
    assert!(load_checkpoint(&step3, ck.runtime()).is_err());

    // the surviving chain reloads bit-identically
    let (loaded, header, manifest) = load_checkpoint(&latest, ck.runtime()).unwrap();
    assert!(loaded.content_eq(&state_at_2));
    assert_eq!(header.extra["step"], Json::Int(2));
    assert_eq!(manifest.delta.as_ref().unwrap().chain_len, 1);

    // a restarted writer resumes the chain from the fallback checkpoint
    let mut ck2 = DeltaCheckpointer::new(
        rt,
        DeltaConfig { chunk_size: CS, max_chain: 8, ..DeltaConfig::default() },
    );
    assert!(ck2.resume_from(&latest).unwrap());
    let mut s2 = state_at_2.snapshot();
    mutate(&mut s2, 0.04, 3);
    let out = ck2.write(&s2, extra(3), &dir.join("step-00000004")).unwrap();
    assert!(!out.is_base, "resume must continue the chain, not restart it");
    assert!(
        out.written_bytes * 2 < out.total_bytes,
        "resumed delta must still skip clean chunks ({} of {})",
        out.written_bytes,
        out.total_bytes
    );
    let (reloaded, _, _) = load_checkpoint(&dir.join("step-00000004"), ck.runtime()).unwrap();
    assert!(reloaded.content_eq(&s2));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn base_delta_delta_chain_is_bit_identical_through_load() {
    let dir = scratch_dir("delta-chain-e2e").unwrap();
    let rt = runtime();
    let mut ck = DeltaCheckpointer::new(
        rt,
        DeltaConfig { chunk_size: CS, max_chain: 8, ..DeltaConfig::default() },
    );
    let mut s = store(7, 25 * CS as usize + 777);
    let mut snapshots = Vec::new();
    for step in 1..=3i64 {
        ck.write(&s, extra(step), &dir.join(format!("step-{step:08}"))).unwrap();
        snapshots.push(s.snapshot());
        mutate(&mut s, 0.03, step as u8);
    }
    // loading any link reproduces the exact serialized state: compare
    // both content and the re-serialized byte stream.
    for (i, snap) in snapshots.iter().enumerate() {
        let step = i as i64 + 1;
        let (loaded, header, _) =
            load_checkpoint(&dir.join(format!("step-{step:08}")), ck.runtime()).unwrap();
        assert!(loaded.content_eq(snap), "step {step}");
        assert_eq!(header.extra["step"], Json::Int(step));
        let a = fastpersist::serialize::writer::SerializedCheckpoint::new(&loaded, extra(step))
            .to_bytes();
        let b = fastpersist::serialize::writer::SerializedCheckpoint::new(snap, extra(step))
            .to_bytes();
        assert_eq!(a, b, "step {step}: reload must be bit-identical");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn compaction_gc_reclaims_dead_segment_bytes_across_prune() {
    use fastpersist::checkpoint::delta::{prune_chain, prune_chain_with, GcPolicy};
    use fastpersist::io::device::DeviceMap;

    let dir = scratch_dir("delta-gc-e2e").unwrap();
    let devices = DeviceMap::single();
    // durable runtime: fsync forces block allocation, so segment GC's
    // st_blocks-based occupancy accounting sees the real layout
    let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist(),
        ..IoRuntimeConfig::default()
    }));
    let mut ck = DeltaCheckpointer::new(
        rt,
        DeltaConfig { chunk_size: CS, max_chain: 2, ..DeltaConfig::default() },
    );
    let mut s = store(13, 16 * CS as usize);
    // base(1) <- d(2) <- d(3), then compaction makes 4 a fresh base
    for step in 1..=4i64 {
        ck.write(&s, extra(step), &dir.join(format!("step-{step:08}"))).unwrap();
        mutate(&mut s, 0.06, step as u8);
    }

    // keep the two newest complete checkpoints: step 4 (base) and
    // step 3 (delta still referencing older chunks). Occupancy 1.0:
    // any dead chunk triggers the sparse segment rewrite.
    let stats =
        prune_chain_with(&dir, 2, &devices, Some(4), GcPolicy { occupancy: 1.0 }).unwrap();
    assert_eq!(stats.removed_dirs + stats.demoted_dirs, 2);
    assert!(stats.demoted_dirs >= 1, "referenced ancestors must be demoted, not removed");
    assert!(
        stats.removed_segments + stats.rewritten_segments > 0,
        "dead segment bytes must be reclaimed: {stats:?}"
    );
    assert!(stats.reclaimed_bytes > 0, "GC must account reclaimed bytes");
    // kept checkpoints still load (rewrite preserved chunk offsets)
    for step in [3i64, 4] {
        let d = dir.join(format!("step-{step:08}"));
        assert!(load_checkpoint(&d, ck.runtime()).is_ok(), "step {step}");
    }

    // once the old chain ages out entirely, its directories disappear
    let stats = prune_chain(&dir, 1, &devices, Some(4)).unwrap();
    assert!(stats.removed_dirs >= 1);
    assert!(!dir.join("step-00000001").exists());
    assert!(!dir.join("step-00000002").exists());
    assert!(!dir.join("step-00000003").exists());
    assert!(load_checkpoint(&dir.join("step-00000004"), ck.runtime()).is_ok());
    std::fs::remove_dir_all(&dir).unwrap();
}
