//! Crash drill for lazy asynchronous checkpointing.
//!
//! The lazy path's durability contract: a generation is either fully
//! published (manifest present, loads bit-identically to its captured
//! snapshot) or invisible (no manifest, recovery skips it) — never
//! partial. A flush that dies between capture and manifest publish must
//! leave recovery on the newest *published* generation, and a restarted
//! writer must resume the delta chain from there.

use std::collections::BTreeMap;
use std::sync::Arc;

use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::lazy::{LazyCheckpointer, LazyConfig};
use fastpersist::checkpoint::load::load_checkpoint;
use fastpersist::checkpoint::manifest::MANIFEST_FILE;
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::io::fault::{FaultKind, FaultPlan, FaultSite};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::prop_assert;
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::training::looper::Trainer;
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;
use fastpersist::Error;

const CS: u64 = 4096;

fn runtime() -> Arc<IoRuntime> {
    runtime_with(None)
}

fn runtime_with(fault: Option<FaultPlan>) -> Arc<IoRuntime> {
    Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig { fault, ..IoConfig::fastpersist().microbench() },
        ..IoRuntimeConfig::default()
    }))
}

fn delta_writer(rt: &Arc<IoRuntime>) -> DeltaCheckpointer {
    DeltaCheckpointer::new(
        Arc::clone(rt),
        DeltaConfig { chunk_size: CS, max_chain: 16, ..DeltaConfig::default() },
    )
}

fn lazy_cfg(max_generations: usize) -> LazyConfig {
    LazyConfig { staging_bytes: 8 << 20, buf_size: 1 << 20, max_generations }
}

fn store(seed: u64, nbytes: usize) -> TensorStore {
    let mut rng = Rng::new(seed);
    let mut s = TensorStore::new();
    let mut data = vec![0u8; nbytes];
    rng.fill_bytes(&mut data);
    s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
    s
}

fn mutate(s: &mut TensorStore, frac: f64, tag: u8) {
    let t = s.get("w").unwrap();
    let mut data = t.data.as_slice().to_vec();
    let n = (data.len() as f64 * frac) as usize;
    let start = data.len() / 4;
    for b in &mut data[start..start + n] {
        *b ^= tag | 1;
    }
    s.update("w", data).unwrap();
}

fn extra(step: i64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step));
    m
}

fn step_dir(dir: &std::path::Path, step: i64) -> std::path::PathBuf {
    dir.join(format!("step-{step:08}"))
}

#[test]
fn killed_lazy_flush_resumes_on_last_durable_generation() {
    let dir = scratch_dir("lazy-crash").unwrap();
    // the flush "dies" in the capture-to-publish window of generation 4:
    // the injected fault fires at the fourth manifest publish (0-based
    // boundary 3), so generation 4's chunks may hit storage but its
    // commit point is never reached
    let fault = FaultPlan::fire_at(FaultKind::Abort, FaultSite::Publish, 3);
    let rt = runtime_with(Some(fault.clone()));
    let mut lazy = LazyCheckpointer::delta(delta_writer(&rt), lazy_cfg(2));

    // three healthy generations, all durable
    let mut s = store(42, 30 * CS as usize);
    let mut snapshots = Vec::new();
    for step in 1..=3i64 {
        lazy.capture(&s, extra(step), step_dir(&dir, step)).unwrap();
        snapshots.push(s.snapshot());
        mutate(&mut s, 0.05, step as u8);
    }
    lazy.wait_all().unwrap();
    let state_at_3 = &snapshots[2];

    lazy.capture(&s, extra(4), step_dir(&dir, 4)).unwrap();
    let err = lazy.wait_all().unwrap_err();
    assert!(matches!(err, Error::FaultTripped(_)), "got {err}");
    assert!(fault.tripped() && fault.halted());
    drop(lazy);

    // recovery: generation 4 is invisible — no manifest, so discovery
    // lands on the newest published generation. "Restart" the process
    // by healing the halted runtime first.
    fault.heal();
    assert!(!step_dir(&dir, 4).join(MANIFEST_FILE).exists());
    let latest = Trainer::latest_checkpoint(&dir).unwrap().unwrap();
    assert!(latest.ends_with("step-00000003"), "latest = {latest:?}");
    let (loaded, header, manifest) = load_checkpoint(&latest, &rt).unwrap();
    assert!(loaded.content_eq(state_at_3));
    assert_eq!(header.extra["step"], Json::Int(3));
    assert_eq!(manifest.delta.as_ref().unwrap().chain_len, 2);

    // a restarted lazy writer re-attaches the chain to the fallback
    // checkpoint and continues it (no fresh base, clean chunks skipped)
    let mut dk = delta_writer(&rt);
    assert!(dk.resume_from(&latest).unwrap());
    let mut lazy2 = LazyCheckpointer::delta(dk, lazy_cfg(2));
    lazy2.capture(&s, extra(4), step_dir(&dir, 4)).unwrap();
    let outcomes = lazy2.finish().unwrap();
    assert_eq!(outcomes.len(), 1);
    let m4 = &outcomes[0].outcome.manifest;
    assert!(m4.is_delta(), "resumed lazy chain must continue, not restart");
    assert_eq!(m4.delta.as_ref().unwrap().chain_len, 3);
    let (reloaded, _, _) = load_checkpoint(&step_dir(&dir, 4), &rt).unwrap();
    assert!(reloaded.content_eq(&s));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn no_generation_is_ever_partially_published() {
    // Property: whatever point in the capture stream the flush dies at,
    // every generation before it is fully durable (loads bit-identically
    // to its captured snapshot) and every generation at/after it is
    // invisible — there is no in-between state.
    let dir = scratch_dir("lazy-prop").unwrap();
    let root = dir.clone();
    fastpersist::prop::forall("no partial lazy generation", 12, |g| {
        let total = g.usize(1, 5) as i64;
        let healthy = g.usize(0, total as usize) as i64;
        let nbytes = g.usize(8, 24) * CS as usize;
        let case_dir = root.join(format!("case-{total}-{healthy}-{nbytes}"));
        // crash point: the flush dies at generation healthy+1's publish
        // boundary (never reached when healthy == total) — everything
        // captured from there on is abandoned mid-flight
        let fault = FaultPlan::fire_at(FaultKind::Abort, FaultSite::Publish, healthy as u64);
        let rt = runtime_with(Some(fault.clone()));
        let mut lazy = LazyCheckpointer::delta(delta_writer(&rt), lazy_cfg(2));

        let mut s = store(nbytes as u64, nbytes);
        let mut snapshots = Vec::new();
        for step in 1..=total {
            let r = lazy.capture(&s, extra(step), step_dir(&case_dir, step));
            if step <= healthy {
                r.unwrap();
            }
            // past the crash point a capture may legitimately surface
            // the flush failure early (backpressure drains a dead
            // generation) — both outcomes are acceptable, so those
            // results are not unwrapped
            snapshots.push(s.snapshot());
            mutate(&mut s, 0.05, step as u8);
        }
        let _ = lazy.wait_all();
        drop(lazy);
        // recovery phase below runs on a "restarted" (healed) runtime
        fault.heal();

        for step in 1..=total {
            let d = step_dir(&case_dir, step);
            if step <= healthy {
                let (loaded, header, _) = load_checkpoint(&d, &rt).unwrap();
                prop_assert!(
                    g,
                    loaded.content_eq(&snapshots[(step - 1) as usize]),
                    "published generation {step} must match its captured snapshot"
                );
                prop_assert!(
                    g,
                    header.extra["step"] == Json::Int(step),
                    "published generation {step} must carry its own extras"
                );
            } else {
                prop_assert!(
                    g,
                    !d.join(MANIFEST_FILE).exists(),
                    "killed generation {step} must not publish a manifest"
                );
                prop_assert!(
                    g,
                    load_checkpoint(&d, &rt).is_err(),
                    "killed generation {step} must not be loadable"
                );
            }
        }
        let latest = Trainer::latest_checkpoint(&case_dir).unwrap();
        if healthy == 0 {
            prop_assert!(g, latest.is_none(), "no published generation, no recovery point");
        } else {
            let latest = latest.unwrap();
            prop_assert!(
                g,
                latest.ends_with(format!("step-{healthy:08}")),
                "recovery must land on the newest published generation, got {latest:?}"
            );
        }
        let _ = std::fs::remove_dir_all(&case_dir);
        true
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn backpressure_bounds_generations_and_staging_bytes() {
    let dir = scratch_dir("lazy-bp").unwrap();
    let rt = runtime();
    // tight budget: 2 buffers, 2 generations — steady state must cycle
    // through the pool without ever allocating past it
    let cfg = LazyConfig { staging_bytes: 2 << 20, buf_size: 1 << 20, max_generations: 2 };
    let mut lazy = LazyCheckpointer::delta(delta_writer(&rt), cfg);
    let mut s = store(7, 200 * 1024);
    for step in 1..=8i64 {
        let cs = lazy.capture(&s, extra(step), step_dir(&dir, step)).unwrap();
        assert!(lazy.in_flight() <= 2, "generation cap violated at step {step}");
        assert_eq!(cs.buffers, 1, "200 KiB fits one 1 MiB buffer");
        mutate(&mut s, 0.1, step as u8);
    }
    lazy.wait_all().unwrap();
    assert_eq!(lazy.in_flight(), 0);
    assert_eq!(lazy.completed.len(), 8);
    let pool = lazy.staging();
    assert!(
        pool.allocations() <= pool.count() as u64,
        "staging must never allocate past the budget ({} > {})",
        pool.allocations(),
        pool.count()
    );
    drop(lazy);
    std::fs::remove_dir_all(&dir).unwrap();
}
