//! Integration: the full three-layer stack through the public API —
//! AOT artifacts → PJRT execution → training → pipelined FastPersist
//! checkpointing → failure → resume.
//!
//! Skipped gracefully when `make artifacts` has not been run.

use std::path::PathBuf;

use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::runtime::artifacts::ArtifactManifest;
use fastpersist::training::looper::{CkptRunMode, Trainer, TrainerConfig};

fn manifest() -> Option<ArtifactManifest> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    ArtifactManifest::load(&dir).ok()
}

fn cfg(model: &str, dir: PathBuf) -> TrainerConfig {
    TrainerConfig {
        model: model.into(),
        steps: 6,
        ckpt_every: 1,
        ckpt_dir: dir,
        mode: CkptRunMode::Pipelined,
        strategy: WriterStrategy::AllReplicas,
        ckpt_strategy: fastpersist::checkpoint::delta::CheckpointStrategy::Full,
        segment_bytes: 64 << 20,
        ckpt_codec: fastpersist::checkpoint::codec::CodecKind::None,
        io: IoConfig::fastpersist().microbench(),
        devices: fastpersist::io::device::DeviceMap::single(),
        dp_writers: 2,
        grad_accum: 1,
        seed: 42,
        keep_last: 0,
        lazy_staging_bytes: 256 << 20,
        lazy_max_generations: 2,
        gc_occupancy: 0.5,
        serve_cache_bytes: 0,
        log_every: 0,
    }
}

#[test]
fn crash_resume_trajectory_is_exact() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let dir = scratch_dir("fs-crash").unwrap();

    // uninterrupted 6-step reference
    let mut reference = Trainer::new(&m, cfg("tiny", dir.join("ref"))).unwrap();
    reference.run().unwrap();

    // victim crashes after 4 steps
    let mut victim_cfg = cfg("tiny", dir.join("victim"));
    victim_cfg.steps = 4;
    let mut victim = Trainer::new(&m, victim_cfg.clone()).unwrap();
    victim.run().unwrap();
    drop(victim);

    // resume and finish
    let mut resume_cfg = victim_cfg;
    resume_cfg.steps = 2;
    let mut resumed = Trainer::resume(&m, resume_cfg).unwrap();
    assert_eq!(resumed.state.step, 4);
    resumed.run().unwrap();

    assert_eq!(resumed.state.step, reference.state.step);
    assert_eq!(resumed.state.theta, reference.state.theta);
    assert_eq!(resumed.state.m, reference.state.m);
    assert_eq!(resumed.state.v, reference.state.v);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn gradient_accumulation_preserves_resume_semantics() {
    let Some(m) = manifest() else { return };
    let dir = scratch_dir("fs-ga").unwrap();
    let mut c = cfg("tiny", dir.join("ga"));
    c.grad_accum = 3;
    c.steps = 4;
    let mut t1 = Trainer::new(&m, c.clone()).unwrap();
    t1.run().unwrap();
    assert_eq!(t1.state.data_cursor, 12); // 4 steps x 3 micro-batches

    let mut c2 = c;
    c2.steps = 2;
    let mut t2 = Trainer::resume(&m, c2).unwrap();
    assert_eq!(t2.state.data_cursor, 12);
    t2.run().unwrap();
    assert_eq!(t2.state.step, 6);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ga_smooths_but_does_not_change_scale_of_loss() {
    let Some(m) = manifest() else { return };
    let dir = scratch_dir("fs-galoss").unwrap();
    let mut c = cfg("tiny", dir.join("x"));
    c.ckpt_every = 0;
    c.mode = CkptRunMode::None;
    c.steps = 3;
    c.grad_accum = 4;
    let mut t = Trainer::new(&m, c).unwrap();
    t.run().unwrap();
    let losses = t.recorder.samples("loss");
    // near ln(vocab)=5.55 at init for tiny (vocab=256)
    assert!((losses[0] - (256f64).ln()).abs() < 0.7, "{losses:?}");
    std::fs::remove_dir_all(&dir).unwrap();
}
