//! API-compatible stub for the PJRT/XLA Rust bindings.
//!
//! The fastpersist crate executes its training computation through the
//! PJRT C API; the real bindings need the native XLA toolchain, which is
//! not available in every build environment. This stub reproduces the
//! exact API surface the crate uses so that:
//!
//! * the whole workspace builds and the I/O / checkpointing / simulator
//!   test suite runs with zero native dependencies;
//! * every *runtime* entry point (client creation, compilation,
//!   execution) returns a descriptive [`Error`], so PJRT-dependent paths
//!   fail fast instead of silently producing garbage — callers gate on
//!   artifact availability and skip.
//!
//! To run real training, point the `xla` dependency in the workspace
//! `Cargo.toml` at the actual bindings; no source change is required.

use std::fmt;

/// Error type mirroring the real bindings' error.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT runtime unavailable (built against the bundled xla stub; \
                 see ARCHITECTURE.md to enable real execution)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the crate inspects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F16,
    F32,
    F64,
    S32,
    S64,
    U8,
}

/// Opaque primitive-type tag used by `Literal::convert`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrimitiveType(pub ElementType);

impl ElementType {
    pub fn primitive_type(self) -> PrimitiveType {
        PrimitiveType(self)
    }
}

/// Host element types accepted by literal constructors/accessors.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host literal placeholder. Constructors succeed (they are pure host
/// operations in the real bindings too); every accessor that would need
/// a real backing buffer errors.
#[derive(Debug, Default, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(Error::unavailable("Literal::ty"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        Err(Error::unavailable("Literal::convert"))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(Error::unavailable("Literal::get_first_element"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module placeholder.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation placeholder.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer placeholder.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client placeholder. `cpu()` fails: there is no device.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

/// Loaded executable placeholder.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_entry_points_error_descriptively() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }

    #[test]
    fn host_constructors_succeed() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_ok());
        let _ = Literal::scalar(1i32);
        assert!(l.to_vec::<f32>().is_err());
    }
}
