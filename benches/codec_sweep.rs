//! Bench: the per-chunk codec stage — `none` vs `lz4` vs `qdelta`
//! across full-snapshot and delta-chain workloads at two mutation
//! rates.
//!
//! Workload: a structured low-entropy payload (512-byte value runs, the
//! shape of embedding/weight pages that block compressors exploit),
//! mutated per step by small-magnitude scattered updates
//! (`wrapping_add(1)` every 64 bytes inside the dirty chunk subset) —
//! the regime where quantized deltas against the chunk's previous bytes
//! crush to near-nothing. Every cell writes a chain through the
//! codec-capable [`DeltaCheckpointer`] (`max_chain = 0` is the
//! full-snapshot shape: every checkpoint a fresh base), then restores
//! the final checkpoint and asserts the decoded bytes are identical to
//! the live store — the bit-identity acceptance check, per cell.
//!
//! Expectations encoded as assertions:
//!   * `none` rows store exactly their raw bytes (ratio 1.0);
//!   * at least one non-`none` codec reaches `bytes_encoded /
//!     bytes_raw <= 0.5` on the delta-chain workload at the low
//!     mutation rate;
//!   * `qdelta` under the full-snapshot shape degrades to raw (a base
//!     has no prior image to diff against) — ratio 1.0 by design.
//!
//! Emits `BENCH_codec.json`: one row per codec × workload × mutation
//! rate, each carrying `bytes_raw` / `bytes_encoded` / `encode_s` /
//! `decode_s` / `ratio` extras.
//!
//!     cargo bench --bench codec_sweep
//!     FASTPERSIST_BENCH_FAST=1 cargo bench --bench codec_sweep   (CI-speed)

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use fastpersist::benchkit::{write_bench_json, BenchGroup, BenchResult};
use fastpersist::checkpoint::codec::CodecKind;
use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::load::{load_checkpoint_with, RestoreOptions};
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::bytes::human;
use fastpersist::util::json::Json;
use fastpersist::util::stats::Summary;
use fastpersist::util::table::Table;

/// Structured low-entropy payload: 512-byte runs of a slowly varying
/// value, the compressible shape of real weight/embedding pages.
fn payload_store(n: usize) -> TensorStore {
    let mut data = vec![0u8; n];
    for (i, b) in data.iter_mut().enumerate() {
        *b = ((i / 512) & 0xff) as u8;
    }
    let mut store = TensorStore::new();
    store.push(Tensor::new("params", DType::U8, vec![n], data).unwrap()).unwrap();
    store
}

/// Small-magnitude scattered updates in `rate` of the chunks: bump one
/// byte every 64 inside each dirty chunk. The diff against the chunk's
/// previous bytes is mostly zeros (qdelta crushes it); the runs between
/// touched bytes stay intact (lz4 still compresses the raw chunk).
fn mutate(store: &mut TensorStore, rate: f64, step: u64, chunk: usize) {
    let t = store.get("params").unwrap();
    let mut data = t.data.as_slice().to_vec();
    let n_chunks = data.len().div_ceil(chunk).max(1);
    let dirty = ((n_chunks as f64 * rate).ceil() as usize).clamp(1, n_chunks);
    let stride = (n_chunks / dirty).max(1);
    for k in 0..dirty {
        let ci = ((step as usize).wrapping_mul(7) + k * stride) % n_chunks;
        let start = ci * chunk;
        let end = (start + chunk).min(data.len());
        let mut off = start + 32;
        while off < end {
            data[off] = data[off].wrapping_add(1);
            off += 64;
        }
    }
    store.update("params", data).unwrap();
}

fn extra(step: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step as i64));
    m
}

/// One grid cell: a chain of `iters` writes under (codec, chain shape,
/// mutation rate), then a decoded restore verified bit-identical to the
/// live store. Returns the bench row and the achieved codec ratio.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    runtime: &Arc<IoRuntime>,
    base: &Path,
    codec: CodecKind,
    kind: &str,
    max_chain: u64,
    rate: f64,
    payload: usize,
    chunk: u64,
    iters: u64,
) -> (BenchResult, f64) {
    let dir = base.join(format!("{}-{}-m{:03}", codec.name(), kind, (rate * 100.0) as u32));
    let mut writer = DeltaCheckpointer::new(
        Arc::clone(runtime),
        DeltaConfig { chunk_size: chunk, max_chain, codec, ..DeltaConfig::default() },
    );
    let mut store = payload_store(payload);
    writer.write(&store, extra(0), &dir.join("step-00000000")).unwrap();

    let mut lats = Vec::new();
    let (mut raw, mut enc, mut stored) = (0u64, 0u64, 0u64);
    let mut encode_s = 0f64;
    for step in 1..=iters {
        mutate(&mut store, rate, step, chunk as usize);
        let t0 = Instant::now();
        let out = writer.write(&store, extra(step), &dir.join(format!("step-{step:08}"))).unwrap();
        lats.push(t0.elapsed().as_secs_f64());
        raw += out.bytes_raw;
        enc += out.bytes_encoded;
        stored += out.written_bytes;
        encode_s += out.encode.as_secs_f64();
    }

    // Bit-identity acceptance: the decoded restore of the chain tip must
    // reproduce the live store exactly, whatever the codec did.
    let loaded = load_checkpoint_with(
        &dir.join(format!("step-{iters:08}")),
        runtime,
        RestoreOptions::default(),
    )
    .unwrap();
    assert_eq!(
        loaded.store.get("params").unwrap().data.as_slice(),
        store.get("params").unwrap().data.as_slice(),
        "decoded restore must be byte-identical ({} {kind} m={rate})",
        codec.name(),
    );
    let decode_s = loaded.stats.decode.as_secs_f64();

    let ratio = if raw == 0 { 1.0 } else { enc as f64 / raw as f64 };
    let result = BenchResult {
        name: format!("codec={} {kind} m={rate:.2}", codec.name()),
        summary: Summary::of(&lats),
        bytes_per_iter: Some(stored / iters),
        extras: vec![
            ("bytes_raw".to_string(), raw as f64),
            ("bytes_encoded".to_string(), enc as f64),
            ("encode_s".to_string(), encode_s),
            ("decode_s".to_string(), decode_s),
            ("ratio".to_string(), ratio),
        ],
    };
    println!("  {}  ratio {ratio:.3}", result.report_line());
    (result, ratio)
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let payload: usize = if fast { 4 << 20 } else { 16 << 20 };
    let iters: u64 = if fast { 3 } else { 6 };
    let chunk: u64 = 256 << 10;
    let rates = [0.02, 0.25];

    let base = scratch_dir("bench-codec").unwrap();
    let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        ..IoRuntimeConfig::default()
    }));
    runtime.staging().prewarm();

    println!(
        "\n=== codec sweep ({} payload, {} chunks, {} steps/cell) ===",
        human(payload as u64),
        human(chunk),
        iters,
    );

    let mut group = BenchGroup::new("codec sweep: none/lz4/qdelta x full/delta x mutation rate");
    let mut table = Table::new(vec!["codec", "shape", "mutation", "stored/ckpt", "ratio"]);
    let mut best_delta_low = f64::INFINITY;
    for codec in [CodecKind::None, CodecKind::Lz4, CodecKind::QuantDelta] {
        for (kind, max_chain) in [("full", 0u64), ("delta", u64::MAX)] {
            for rate in rates {
                let (r, ratio) = run_cell(
                    &runtime, &base, codec, kind, max_chain, rate, payload, chunk, iters,
                );
                if codec == CodecKind::None {
                    assert!(
                        (ratio - 1.0).abs() < 1e-9,
                        "codec none must store raw bytes exactly, got ratio {ratio}"
                    );
                }
                if codec != CodecKind::None && kind == "delta" && rate == rates[0] {
                    best_delta_low = best_delta_low.min(ratio);
                }
                table.row(vec![
                    codec.name().to_string(),
                    kind.to_string(),
                    format!("{:.0}%", rate * 100.0),
                    human(r.bytes_per_iter.unwrap_or(0)),
                    format!("{ratio:.3}"),
                ]);
                group.results.push(r);
            }
        }
    }
    println!("{}", table.render());
    // The headline acceptance: on the delta-chain workload at the low
    // mutation rate, at least one codec must at least halve the stored
    // bytes.
    assert!(
        best_delta_low <= 0.5,
        "no codec reached bytes_encoded/bytes_raw <= 0.5 on the low-mutation \
         delta workload (best {best_delta_low:.3})"
    );
    println!(
        "best low-mutation delta-chain ratio {best_delta_low:.3} (target: <= 0.5)"
    );

    let _ = write_bench_json("codec", &[&group]);
    let _ = std::fs::remove_dir_all(&base);
}
