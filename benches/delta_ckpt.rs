//! Bench: full vs incremental (delta) checkpointing — bytes written,
//! latency, WriteJob (segment) counts and fsyncs per checkpoint,
//! through one shared [`IoRuntime`].
//!
//! Workload: a model-state payload where <5% of the parameters mutate
//! per iteration (the sparse-update regime of embedding-heavy models —
//! the case Check-N-Run's differential checkpointing targets). Each
//! iteration is checkpointed twice: as a full snapshot through the
//! parallel [`CheckpointEngine`], and as a chunk-granular delta through
//! [`DeltaCheckpointer`]. The delta side should write an order of
//! magnitude fewer bytes (acceptance: ≥80% fewer at <5% mutation), and
//! — since segment stores — a bounded number of WriteJobs per
//! checkpoint however many chunks are dirty.
//!
//! A separate durable section (fsync on) demonstrates the coalescing
//! win directly: a base of N chunks issues one fsync per *segment*,
//! not one per chunk.
//!
//! Emits `BENCH_delta.json` (benchkit JSON) for trajectory tracking:
//! `bytes_per_iter` on the segment rows is **bytes per WriteJob**, and
//! row names carry jobs/fsyncs per checkpoint.
//!
//!     cargo bench --bench delta_ckpt
//!     FASTPERSIST_BENCH_FAST=1 cargo bench --bench delta_ckpt   (CI-speed)

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use fastpersist::benchkit::{write_bench_json, BenchGroup, BenchResult};
use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::bytes::human;
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;
use fastpersist::util::stats::Summary;
use fastpersist::util::table::Table;

/// Mutate `frac` of the payload per step: a contiguous hot region whose
/// position advances each step (sparse, locality-friendly updates).
fn mutate(store: &mut TensorStore, frac: f64, step: u64) {
    let t = store.get("params").unwrap();
    let mut data = t.data.as_slice().to_vec();
    let n = ((data.len() as f64) * frac) as usize;
    let start = (step as usize * 3 * n) % (data.len() - n.max(1));
    let mut rng = Rng::new(step ^ 0xde17a);
    rng.fill_bytes(&mut data[start..start + n]);
    store.update("params", data).unwrap();
}

fn extra(step: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step as i64));
    m
}

fn payload_store(payload: usize) -> TensorStore {
    let mut store = TensorStore::new();
    let mut data = vec![0u8; payload];
    Rng::new(1).fill_bytes(&mut data);
    store.push(Tensor::new("params", DType::U8, vec![payload], data).unwrap()).unwrap();
    store
}

/// Durable section: count WriteJobs and fsyncs for a base + one delta.
fn fsync_accounting(payload: usize, chunk_size: u64, group: &mut BenchGroup) {
    let base = scratch_dir("bench-delta-fsync").unwrap();
    let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist(), // durable: fsync on finish
        ..IoRuntimeConfig::default()
    }));
    let mut delta = DeltaCheckpointer::new(
        Arc::clone(&runtime),
        DeltaConfig { chunk_size, max_chain: u64::MAX, ..DeltaConfig::default() },
    );
    let mut store = payload_store(payload);

    let t0 = Instant::now();
    let b = delta.write(&store, extra(0), &base.join("step-00000000")).unwrap();
    let base_lat = t0.elapsed().as_secs_f64();
    mutate(&mut store, 0.04, 1);
    let t0 = Instant::now();
    let d = delta.write(&store, extra(1), &base.join("step-00000001")).unwrap();
    let delta_lat = t0.elapsed().as_secs_f64();

    // direct/bounce/queue-depth accounting across the segment writes
    let qd = |stats: &[fastpersist::io::WriteStats]| {
        stats.iter().map(|s| s.queue_depth_max).max().unwrap_or(0)
    };
    let direct_bytes = |stats: &[fastpersist::io::WriteStats]| {
        stats.iter().map(|s| s.direct_bytes).sum::<u64>()
    };
    println!(
        "durable base:  {} chunks -> {} segment WriteJobs, {} fsyncs ({} per job); \
         direct {} over {} extents, bounce {}, qd_max {}",
        b.chunks_total,
        b.segments_written,
        b.fsyncs,
        human(b.bytes_per_job()),
        human(direct_bytes(&b.stats)),
        b.direct_extents(),
        human(b.bounce_bytes()),
        qd(&b.stats),
    );
    println!(
        "durable delta: {} dirty chunks -> {} segment WriteJobs, {} fsyncs ({} per job); \
         direct {} over {} extents, bounce {}, qd_max {}",
        d.chunks_written,
        d.segments_written,
        d.fsyncs,
        human(d.bytes_per_job()),
        human(direct_bytes(&d.stats)),
        d.direct_extents(),
        human(d.bounce_bytes()),
        qd(&d.stats),
    );
    assert_eq!(b.fsyncs, b.segments_written as u64, "one fsync per segment");
    assert!(
        (b.segments_written as usize) < b.chunks_total,
        "base must coalesce chunks into fewer segment writes"
    );
    group.results.push(BenchResult {
        name: format!(
            "durable-base ({} chunks, {} jobs, {} fsyncs, direct_bytes={} \
             direct_extents={} bounce_bytes={} qd_max={})",
            b.chunks_total,
            b.segments_written,
            b.fsyncs,
            direct_bytes(&b.stats),
            b.direct_extents(),
            b.bounce_bytes(),
            qd(&b.stats),
        ),
        summary: Summary::of(&[base_lat]),
        bytes_per_iter: Some(b.bytes_per_job()),
        extras: Vec::new(),
    });
    group.results.push(BenchResult {
        name: format!(
            "durable-delta ({} dirty chunks, {} jobs, {} fsyncs, direct_bytes={} \
             direct_extents={} bounce_bytes={} qd_max={})",
            d.chunks_written,
            d.segments_written,
            d.fsyncs,
            direct_bytes(&d.stats),
            d.direct_extents(),
            d.bounce_bytes(),
            qd(&d.stats),
        ),
        summary: Summary::of(&[delta_lat]),
        bytes_per_iter: Some(d.bytes_per_job()),
        extras: Vec::new(),
    });
    let _ = std::fs::remove_dir_all(&base);
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let payload: usize = if fast { 8 << 20 } else { 32 << 20 };
    let iters: u64 = if fast { 5 } else { 10 };
    let mutation = 0.04; // <5% of parameters per iteration
    let chunk_size: u64 = 256 << 10;

    let base = scratch_dir("bench-delta").unwrap();
    let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        ..IoRuntimeConfig::default()
    }));
    runtime.staging().prewarm();
    let engine =
        CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas);
    let mut delta = DeltaCheckpointer::new(
        Arc::clone(&runtime),
        DeltaConfig { chunk_size, max_chain: u64::MAX, ..DeltaConfig::default() },
    );

    let mut store = payload_store(payload);

    println!(
        "\n=== delta vs full checkpoint ({} payload, {:.0}% mutation/iter, {} chunks) ===",
        human(payload as u64),
        mutation * 100.0,
        human(chunk_size),
    );

    // warm both paths (first delta write is the chain base = full cost)
    engine.write_single(&store, extra(0), &base.join("full").join("step-00000000")).unwrap();
    let warm = delta.write(&store, extra(0), &base.join("chain").join("step-00000000")).unwrap();
    println!(
        "base: {} chunks coalesced into {} segment WriteJobs ({} per job)",
        warm.chunks_total,
        warm.segments_written,
        human(warm.bytes_per_job()),
    );

    let mut full_lat = Vec::new();
    let mut delta_lat = Vec::new();
    let mut full_bytes = 0u64;
    let mut delta_bytes = 0u64;
    let mut delta_jobs = 0u64;
    let mut delta_fsyncs = 0u64;
    for step in 1..=iters {
        mutate(&mut store, mutation, step);
        let t0 = Instant::now();
        let out = engine
            .write_single(&store, extra(step), &base.join("full").join(format!("step-{step:08}")))
            .unwrap();
        full_lat.push(t0.elapsed().as_secs_f64());
        full_bytes += out.total_bytes;
        let t0 = Instant::now();
        let out = delta
            .write(&store, extra(step), &base.join("chain").join(format!("step-{step:08}")))
            .unwrap();
        delta_lat.push(t0.elapsed().as_secs_f64());
        delta_bytes += out.written_bytes;
        delta_jobs += out.segments_written as u64;
        delta_fsyncs += out.fsyncs;
        assert!(!out.is_base, "steady-state writes must be deltas");
    }

    let saved = 1.0 - delta_bytes as f64 / full_bytes as f64;
    let full = Summary::of(&full_lat);
    let dlt = Summary::of(&delta_lat);
    let jobs_per_ckpt = delta_jobs as f64 / iters as f64;
    let bytes_per_job = if delta_jobs == 0 { 0 } else { delta_bytes / delta_jobs };
    let mut table = Table::new(vec![
        "path", "bytes/ckpt", "latency p50 (ms)", "jobs/ckpt", "bytes/job", "written vs full",
    ]);
    table.row(vec![
        "full snapshot".into(),
        human(full_bytes / iters),
        format!("{:.2}", full.p50 * 1e3),
        "1".into(),
        human(full_bytes / iters),
        "100%".into(),
    ]);
    table.row(vec![
        "delta (segment-packed)".into(),
        human(delta_bytes / iters),
        format!("{:.2}", dlt.p50 * 1e3),
        format!("{jobs_per_ckpt:.1}"),
        human(bytes_per_job),
        format!("{:.1}%", (1.0 - saved) * 100.0),
    ]);
    println!("{}", table.render());
    println!(
        "delta writes {:.1}% fewer bytes than full at {:.0}% mutation (target: >=80%); \
         fsyncs/ckpt in this microbench config: {:.1} (durability off)",
        saved * 100.0,
        mutation * 100.0,
        delta_fsyncs as f64 / iters as f64,
    );

    let mut group = BenchGroup::new("delta vs full checkpoint bytes/latency");
    group.results.push(BenchResult {
        name: "full-snapshot".into(),
        summary: full,
        bytes_per_iter: Some(full_bytes / iters),
        extras: Vec::new(),
    });
    group.results.push(BenchResult {
        name: format!(
            "delta-incremental (writes {:.1}% of full, {jobs_per_ckpt:.1} jobs/ckpt)",
            (1.0 - saved) * 100.0
        ),
        summary: dlt,
        bytes_per_iter: Some(delta_bytes / iters),
        extras: Vec::new(),
    });

    println!("\n=== segment coalescing, durable (fsync per WriteJob) ===");
    let mut seg_group = BenchGroup::new("segment coalescing: WriteJobs + fsyncs per checkpoint");
    fsync_accounting(if fast { 4 << 20 } else { 16 << 20 }, chunk_size, &mut seg_group);

    let _ = write_bench_json("delta", &[&group, &seg_group]);
    let _ = std::fs::remove_dir_all(&base);
}
