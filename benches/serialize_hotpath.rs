//! Bench: checkpoint-serialization hot path — the CPU-side costs the
//! engine pays per checkpoint regardless of storage: snapshot, header
//! encode, stream digest, range emission, partition planning.
//!
//! These run on every iteration in the per-iteration-checkpointing
//! regime, so they must stay far below the write time (§Perf targets).

use std::collections::BTreeMap;

use fastpersist::benchkit::BenchGroup;
use fastpersist::checkpoint::plan::WritePlan;
use fastpersist::serialize::format::checksum64_slice;
use fastpersist::serialize::writer::SerializedCheckpoint;
use fastpersist::tensor::{DType, Tensor, TensorStore};

fn store_mb(mb: usize) -> TensorStore {
    let mut s = TensorStore::new();
    // a realistic tensor mix: a few large + many small
    let large = mb * (1 << 20) / 4;
    for i in 0..3 {
        s.push(Tensor::new(&format!("big{i}"), DType::U8, vec![large], vec![7u8; large]).unwrap())
            .unwrap();
    }
    for i in 0..64 {
        s.push(Tensor::new(&format!("small{i}"), DType::F32, vec![256], vec![1u8; 1024]).unwrap())
            .unwrap();
    }
    s
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let mb = if fast { 64 } else { 256 };
    let store = store_mb(mb);
    let bytes = store.total_bytes();

    let mut group = BenchGroup::start(&format!("serialize hot path ({mb} MB store)"));
    group.bench("snapshot (Arc clones)", || {
        std::hint::black_box(store.snapshot());
    });
    group.bench_bytes("SerializedCheckpoint::new (header + digest)", bytes, || {
        std::hint::black_box(SerializedCheckpoint::new(&store, BTreeMap::new()));
    });
    let ser = SerializedCheckpoint::new(&store, BTreeMap::new());
    group.bench_bytes("emit_range full stream", ser.total_len(), || {
        let mut n = 0u64;
        ser.emit_range(0, ser.total_len(), &mut |p| {
            n += p.len() as u64;
            Ok(())
        })
        .unwrap();
        std::hint::black_box(n);
    });
    let payload = vec![3u8; (bytes as usize).min(64 << 20)];
    group.bench_bytes("checksum64_slice", payload.len() as u64, || {
        std::hint::black_box(checksum64_slice(&payload));
    });
    group.bench("WritePlan::balanced DP=1024", || {
        let writers: Vec<usize> = (0..1024).collect();
        let plan = WritePlan::balanced(173_000_000_000, &writers).unwrap();
        std::hint::black_box(plan);
    });
}
