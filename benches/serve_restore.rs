//! Bench: **restore-at-scale** through the serve layer — 1/4/16
//! concurrent tenants restoring a delta-chain pool through one
//! [`fastpersist::checkpoint::serve::RestoreService`], cold vs warm
//! segment cache, mmap zero-copy vs buffered-pread serving.
//!
//! Workload: a base + 5-delta chain (segment stores, ~15%
//! mutation/step) restored by N scoped tenant threads, each with its
//! own [`RestoreSession`], steps assigned round-robin so tenants
//! overlap on the same segments:
//!
//! * **cold** — a fresh service: every segment read misses the cache
//!   and goes through the fair scheduler to disk;
//! * **warm** — the same service again: segment reads hit the
//!   byte-budgeted cache (mmap'd images by default);
//! * **pread** — `ServeConfig { mmap: false }`: the buffered-read
//!   fallback path, cached as heap images.
//!
//! Every restore is content-verified against the written state, so the
//! numbers are for *correct* restores only. Row names carry the cache
//! counters; each row's JSON gets a `p99_s` extra (tail latency is the
//! serving-layer acceptance metric). Deterministic asserts: warm passes
//! must hit the cache, the cache must stay within budget, and the entry
//! lifecycle must reconcile — timing is reported, never asserted.
//!
//!     cargo bench --bench serve_restore
//!     FASTPERSIST_BENCH_FAST=1 cargo bench --bench serve_restore   (CI-speed)
//!
//! [`RestoreSession`]: fastpersist::checkpoint::serve::RestoreSession

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use fastpersist::benchkit::{write_bench_json, BenchGroup, BenchResult};
use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::serve::{CacheStats, RestoreService, ServeConfig};
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::bytes::human;
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;
use fastpersist::util::stats::{percentile, Summary};
use fastpersist::util::table::Table;

fn extra(step: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step as i64));
    m
}

fn payload_store(payload: usize) -> TensorStore {
    let mut store = TensorStore::new();
    let mut data = vec![0u8; payload];
    Rng::new(17).fill_bytes(&mut data);
    store.push(Tensor::new("params", DType::U8, vec![payload], data).unwrap()).unwrap();
    store
}

fn mutate(store: &mut TensorStore, frac: f64, step: u64) {
    let t = store.get("params").unwrap();
    let mut data = t.data.as_slice().to_vec();
    let n = ((data.len() as f64) * frac) as usize;
    let start = (step as usize * 3 * n) % (data.len() - n.max(1));
    Rng::new(step ^ 0x5e47e).fill_bytes(&mut data[start..start + n]);
    store.update("params", data).unwrap();
}

/// Base + `deltas` chain under `root`; returns each step's dir and
/// expected state.
fn write_chain(
    root: &std::path::Path,
    runtime: &Arc<IoRuntime>,
    payload: usize,
    deltas: u64,
) -> (Vec<PathBuf>, Vec<TensorStore>) {
    let mut delta = DeltaCheckpointer::new(
        Arc::clone(runtime),
        DeltaConfig { chunk_size: 256 << 10, max_chain: u64::MAX, ..DeltaConfig::default() },
    );
    let mut store = payload_store(payload);
    let mut dirs = Vec::new();
    let mut states = Vec::new();
    for step in 0..=deltas {
        if step > 0 {
            mutate(&mut store, 0.15, step);
        }
        let dir = root.join(format!("step-{step:08}"));
        delta.write(&store, extra(step), &dir).unwrap();
        dirs.push(dir);
        states.push(store.clone());
    }
    (dirs, states)
}

/// One pass: `tenants` scoped threads, each with its own session,
/// restoring `per_tenant` round-robin-assigned steps. Returns every
/// per-restore latency; each restore is content-verified.
fn run_pass(
    svc: &Arc<RestoreService>,
    dirs: &[PathBuf],
    states: &[TensorStore],
    tenants: usize,
    per_tenant: usize,
) -> Vec<f64> {
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..tenants {
            let svc = Arc::clone(svc);
            handles.push(scope.spawn(move || {
                let session = svc.session(format!("tenant-{t}"));
                let mut lat = Vec::with_capacity(per_tenant);
                for k in 0..per_tenant {
                    let i = (t * 7 + k) % dirs.len();
                    let t0 = Instant::now();
                    let got = session.restore(&dirs[i]).unwrap();
                    lat.push(t0.elapsed().as_secs_f64());
                    assert!(got.store.content_eq(&states[i]), "tenant {t}: step {i} diverged");
                }
                lat
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    })
}

/// Row with the cache counters in the name and tail latency as a
/// `p99_s` extra.
fn row(label: String, mut lat: Vec<f64>, bytes: u64, s: &CacheStats) -> BenchResult {
    lat.sort_by(f64::total_cmp);
    let p99 = percentile(&lat, 0.99);
    BenchResult {
        name: format!(
            "{label} ({} hits, {} misses, {} cached)",
            s.hits,
            s.misses,
            human(s.bytes_held)
        ),
        summary: Summary::of(&lat),
        bytes_per_iter: Some(bytes),
        extras: Vec::new(),
    }
    .with_extra("p99_s", p99)
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let payload: usize = if fast { 4 << 20 } else { 16 << 20 };
    let per_tenant: usize = if fast { 3 } else { 6 };
    let deltas: u64 = 5;
    let budget: u64 = 256 << 20;

    let base = scratch_dir("bench-serve").unwrap();
    let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        reader_threads: 8,
        ..IoRuntimeConfig::default()
    }));
    runtime.staging().prewarm();

    let (dirs, states) = write_chain(&base.join("chain"), &runtime, payload, deltas);
    let bytes = payload as u64;
    let mut groups: Vec<BenchGroup> = Vec::new();
    let mut table =
        Table::new(vec!["tenants", "mode", "p50 (ms)", "p99 (ms)", "hits", "misses"]);

    for tenants in [1usize, 4, 16] {
        let mut group = BenchGroup::new(&format!(
            "serve {} x {} steps to {tenants} tenant(s): cold vs warm, mmap vs pread",
            human(payload as u64),
            dirs.len()
        ));
        for (mode, mmap) in [("mmap", true), ("pread", false)] {
            // fresh service per mode: the cold pass fills the cache,
            // the warm pass reuses it
            let svc = RestoreService::new(
                Arc::clone(&runtime),
                ServeConfig { admit_after: 1, mmap, ..ServeConfig::with_cache(budget) },
            );
            for phase in ["cold", "warm"] {
                let lat = run_pass(&svc, &dirs, &states, tenants, per_tenant);
                let s = svc.cache_stats();
                if phase == "warm" {
                    // deterministic acceptance: warm passes hit the cache
                    assert!(s.hits > 0, "warm {mode} pass must hit the cache: {s:?}");
                }
                assert!(s.bytes_held <= s.budget, "cache over budget: {s:?}");
                assert_eq!(
                    s.entries,
                    s.admitted - s.evicted - s.invalidated,
                    "entry lifecycle must reconcile: {s:?}"
                );
                let r = row(format!("{tenants}t {phase} {mode}"), lat, bytes, &s);
                table.row(vec![
                    tenants.to_string(),
                    format!("{phase} {mode}"),
                    format!("{:.2}", r.summary.p50 * 1e3),
                    format!("{:.2}", r.extras[0].1 * 1e3),
                    s.hits.to_string(),
                    s.misses.to_string(),
                ]);
                group.results.push(r);
            }
        }
        groups.push(group);
    }

    println!("{}", table.render());
    let refs: Vec<&BenchGroup> = groups.iter().collect();
    let _ = write_bench_json("serve", &refs);
    let _ = std::fs::remove_dir_all(&base);
}
