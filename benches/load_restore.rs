//! Bench: checkpoint **restore** through the ReadRuntime — full-snapshot
//! vs delta-chain reloads over 1/2/4 devices, coalesced vs naive read
//! plans, through one shared [`IoRuntime`].
//!
//! The write path has had a measured runtime since PR 1; this bench
//! makes restore a measured path too. Workload: a checkpoint written as
//! (a) a DP=8 full snapshot (8 partition files, device-striped) and
//! (b) a base + 3-delta chain (segment stores, <5% mutation/iter), then
//! restored repeatedly:
//!
//! * **coalesced** — the default plan: byte-adjacent chunks merge into
//!   single preads ([`fastpersist::io::read::plan_runs`]);
//! * **naive** — `RestoreOptions { coalesce: false }`: one pread per
//!   chunk, the pre-ReadRuntime behavior.
//!
//! Row names carry the job/run/pread counters so the coalescing effect
//! is visible next to the latency; the counter relation
//! `preads(coalesced) <= preads(naive)` is asserted (deterministic),
//! and the 4-device sweep prints the latency comparison the acceptance
//! criterion reads from `BENCH_load.json`.
//!
//!     cargo bench --bench load_restore
//!     FASTPERSIST_BENCH_FAST=1 cargo bench --bench load_restore   (CI-speed)

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use fastpersist::benchkit::{write_bench_json, BenchGroup, BenchResult};
use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::load::{load_checkpoint_with, LoadedCheckpoint, RestoreOptions};
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::topology::RankPlacement;
use fastpersist::io::device::DeviceMap;
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::bytes::human;
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;
use fastpersist::util::stats::Summary;
use fastpersist::util::table::Table;

fn extra(step: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step as i64));
    m
}

fn payload_store(payload: usize) -> TensorStore {
    let mut store = TensorStore::new();
    let mut data = vec![0u8; payload];
    Rng::new(11).fill_bytes(&mut data);
    store.push(Tensor::new("params", DType::U8, vec![payload], data).unwrap()).unwrap();
    store
}

fn mutate(store: &mut TensorStore, frac: f64, step: u64) {
    let t = store.get("params").unwrap();
    let mut data = t.data.as_slice().to_vec();
    let n = ((data.len() as f64) * frac) as usize;
    let start = (step as usize * 3 * n) % (data.len() - n.max(1));
    Rng::new(step ^ 0x10ad).fill_bytes(&mut data[start..start + n]);
    store.update("params", data).unwrap();
}

fn dp_group(n: usize) -> Vec<RankPlacement> {
    (0..n).map(|r| RankPlacement { rank: r, node: 0, socket: r % 2, local_gpu: r }).collect()
}

/// Restore `reps` times; returns (latency summary, last load) and
/// sanity-checks the content every time.
fn measure(
    dir: &std::path::Path,
    runtime: &IoRuntime,
    opts: RestoreOptions,
    reps: usize,
    expect: &TensorStore,
) -> (Summary, LoadedCheckpoint) {
    let mut lat = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let loaded = load_checkpoint_with(dir, runtime, opts).unwrap();
        lat.push(t0.elapsed().as_secs_f64());
        assert!(loaded.store.content_eq(expect), "restore diverged at {dir:?}");
        last = Some(loaded);
    }
    (Summary::of(&lat), last.unwrap())
}

fn row(label: String, summary: Summary, loaded: &LoadedCheckpoint) -> BenchResult {
    BenchResult {
        name: format!(
            "{label} ({} jobs, {} runs, {} preads, {} coalesced)",
            loaded.stats.jobs, loaded.stats.runs, loaded.stats.preads, loaded.stats.coalesced
        ),
        summary,
        bytes_per_iter: Some(loaded.manifest.total_len),
        extras: Vec::new(),
    }
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let payload: usize = if fast { 8 << 20 } else { 32 << 20 };
    let reps: usize = if fast { 3 } else { 7 };
    let chunk_size: u64 = 256 << 10;
    let chain_deltas: u64 = 3;

    let base = scratch_dir("bench-load").unwrap();
    let mut groups: Vec<BenchGroup> = Vec::new();
    let mut four_dev: Option<(Summary, Summary)> = None;

    for ndev in [1usize, 2, 4] {
        let devices = if ndev == 1 {
            DeviceMap::single()
        } else {
            DeviceMap::simulated(ndev, &base.join(format!("ssds{ndev}"))).unwrap()
        };
        let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            devices,
            writer_threads: 8,
            reader_threads: 8,
            ..IoRuntimeConfig::default()
        }));
        runtime.staging().prewarm();
        let root = base.join(format!("dev{ndev}"));

        // (a) full snapshot, DP=8
        let engine =
            CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas);
        let full_store = payload_store(payload);
        let full_dir = root.join("full");
        engine.write(&full_store, extra(0), &full_dir, &dp_group(8)).unwrap();

        // (b) base + Δ³ chain, segment stores
        let mut delta = DeltaCheckpointer::new(
            Arc::clone(&runtime),
            DeltaConfig { chunk_size, max_chain: u64::MAX, ..DeltaConfig::default() },
        );
        let mut chain_store = payload_store(payload);
        delta.write(&chain_store, extra(0), &root.join("chain/step-00000000")).unwrap();
        let mut tail = root.join("chain/step-00000000");
        for step in 1..=chain_deltas {
            mutate(&mut chain_store, 0.04, step);
            tail = root.join(format!("chain/step-{step:08}"));
            delta.write(&chain_store, extra(step), &tail).unwrap();
        }

        let mut group = BenchGroup::new(&format!(
            "restore {} over {ndev} device(s): full vs delta chain, coalesced vs naive",
            human(payload as u64)
        ));
        let coalesced = RestoreOptions::default();
        let naive = RestoreOptions { coalesce: false };

        let (s, l) = measure(&full_dir, &runtime, coalesced, reps, &full_store);
        group.results.push(row(format!("full dp8 {ndev}dev coalesced"), s, &l));
        let (s, l) = measure(&full_dir, &runtime, naive, reps, &full_store);
        group.results.push(row(format!("full dp8 {ndev}dev naive"), s, &l));

        let (cs, cl) = measure(&tail, &runtime, coalesced, reps, &chain_store);
        group.results.push(row(format!("delta-chain {ndev}dev coalesced"), cs.clone(), &cl));
        let (ns, nl) = measure(&tail, &runtime, naive, reps, &chain_store);
        group.results.push(row(format!("delta-chain {ndev}dev naive"), ns.clone(), &nl));

        // deterministic acceptance: coalescing only removes preads
        assert!(
            cl.stats.preads <= nl.stats.preads,
            "coalesced plan must not issue more preads ({} vs {})",
            cl.stats.preads,
            nl.stats.preads
        );
        assert!(cl.stats.coalesced > 0, "chain restore must find adjacent chunks to merge");

        let mut table = Table::new(vec![
            "restore", "p50 (ms)", "GB/s", "jobs", "runs", "preads", "coalesced",
        ]);
        for (name, s, l) in
            [("delta coalesced", &cs, &cl), ("delta naive", &ns, &nl)]
        {
            table.row(vec![
                format!("{name} {ndev}dev"),
                format!("{:.2}", s.p50 * 1e3),
                format!("{:.2}", fastpersist::util::bytes::gbps(l.manifest.total_len, s.p50)),
                l.stats.jobs.to_string(),
                l.stats.runs.to_string(),
                l.stats.preads.to_string(),
                l.stats.coalesced.to_string(),
            ]);
        }
        println!("{}", table.render());
        if ndev == 4 {
            four_dev = Some((cs, ns));
        }
        groups.push(group);
        let _ = std::fs::remove_dir_all(&root);
    }

    if let Some((c, n)) = four_dev {
        println!(
            "4-device delta-chain restore: coalesced p50 {:.2} ms vs naive {:.2} ms ({})",
            c.p50 * 1e3,
            n.p50 * 1e3,
            if c.p50 <= n.p50 { "coalesced ahead" } else { "within noise — see preads" },
        );
    }
    let refs: Vec<&BenchGroup> = groups.iter().collect();
    let _ = write_bench_json("load", &refs);
    let _ = std::fs::remove_dir_all(&base);
}
