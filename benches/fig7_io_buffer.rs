//! Bench: Figure 7 family — single-writer write path on real disk.
//!
//! Times the three engines (buffered baseline, direct single-buffer,
//! direct double-buffer) over checkpoint and IO-buffer sizes, in
//! pagecache-as-NVMe mode (see `figures::fig7` for the substrate note).
//!
//! Each configuration runs through a persistent [`IoRuntime`]
//! constructed once *outside* the timed region, so iterations measure
//! the steady-state write path (recycled staging buffers, persistent
//! writer/drain threads) — the regime the paper's Fig. 7 sweeps.
//!
//!     cargo bench --bench fig7_io_buffer
//!     FASTPERSIST_BENCH_FAST=1 cargo bench ...   (CI-speed)
//!
//! Emits `BENCH_fig7.json` (benchkit JSON) for trajectory tracking.

use std::sync::Arc;

use fastpersist::benchkit::{write_bench_json, BenchGroup};
use fastpersist::io::engine::{EngineKind, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig, WriteJob};
use fastpersist::util::bytes::MB;

fn runtime_for(cfg: IoConfig) -> IoRuntime {
    IoRuntime::new(IoRuntimeConfig { io: cfg, ..IoRuntimeConfig::default() })
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let dir = fastpersist::io::engine::scratch_dir("bench-fig7").unwrap();
    let ckpt_sizes: &[u64] = if fast { &[16, 128] } else { &[16, 64, 256] };
    let buf_sizes: &[u64] = if fast { &[8] } else { &[2, 8, 32] };

    let mut groups = Vec::new();
    for &ck in ckpt_sizes {
        let data = Arc::new(vec![0x55u8; (ck * MB) as usize]);
        let mut group = BenchGroup::start(&format!("fig7: {ck} MB checkpoint"));
        let path = dir.join("bench.bin");
        let baseline = runtime_for(IoConfig::baseline().microbench());
        group.bench_bytes("baseline buffered 64KB chunks", data.len() as u64, || {
            baseline
                .submit(WriteJob::bytes(Arc::clone(&data), path.clone()))
                .wait()
                .unwrap();
        });
        for &buf in buf_sizes {
            for (name, kind) in
                [("single", EngineKind::DirectSingle), ("double", EngineKind::DirectDouble)]
            {
                let rt = runtime_for(
                    IoConfig::with_kind(kind)
                        .with_buf_size((buf * MB) as usize)
                        .microbench(),
                );
                group.bench_bytes(
                    &format!("direct-{name} io_buf={buf}MB"),
                    data.len() as u64,
                    || {
                        rt.submit(WriteJob::bytes(Arc::clone(&data), path.clone()))
                            .wait()
                            .unwrap();
                    },
                );
            }
        }
        groups.push(group);
    }
    // Direct-path counter group (durable, probe-gated O_DIRECT): one
    // real write per engine kind with the WriteStats counters in the
    // row names, so BENCH_fig7.json proves whether the direct path was
    // actually taken on this filesystem (direct_bytes > 0) or the
    // probed fallback engaged (direct_bytes == 0).
    let mut counters = BenchGroup::start("fig7: direct/bounce/queue-depth counters (durable)");
    let data = Arc::new(vec![0x5au8; (8 * MB) as usize + 777]);
    for (name, kind) in [
        ("buffered", EngineKind::Buffered),
        ("direct-single", EngineKind::DirectSingle),
        ("direct-double", EngineKind::DirectDouble),
    ] {
        let rt = runtime_for(IoConfig::with_kind(kind)); // durable, try_o_direct on
        let path = dir.join(format!("counters-{name}.bin"));
        let s = rt
            .submit(WriteJob::bytes(Arc::clone(&data), path.clone()))
            .wait()
            .unwrap();
        assert_eq!(s.total_bytes, data.len() as u64);
        counters.bench_bytes(
            &format!(
                "{name} o_direct={} direct_bytes={} direct_extents={} bounce_bytes={} \
                 qd_max={}",
                s.o_direct, s.direct_bytes, s.direct_extents, s.bounce_bytes, s.queue_depth_max
            ),
            data.len() as u64,
            || {
                rt.submit(WriteJob::bytes(Arc::clone(&data), path.clone()))
                    .wait()
                    .unwrap();
            },
        );
    }
    groups.push(counters);

    let refs: Vec<&BenchGroup> = groups.iter().collect();
    let _ = write_bench_json("fig7", &refs);
    let _ = std::fs::remove_dir_all(&dir);
}
