//! Bench: Figure 7 family — single-writer write path on real disk.
//!
//! Times the three engines (buffered baseline, direct single-buffer,
//! direct double-buffer) over checkpoint and IO-buffer sizes, in
//! pagecache-as-NVMe mode (see `figures::fig7` for the substrate note).
//!
//!     cargo bench --bench fig7_io_buffer
//!     FASTPERSIST_BENCH_FAST=1 cargo bench ...   (CI-speed)

use fastpersist::benchkit::BenchGroup;
use fastpersist::io::engine::{write_file, EngineKind, IoConfig};
use fastpersist::util::bytes::MB;

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let dir = fastpersist::io::engine::scratch_dir("bench-fig7").unwrap();
    let ckpt_sizes: &[u64] = if fast { &[16, 128] } else { &[16, 64, 256] };
    let buf_sizes: &[u64] = if fast { &[8] } else { &[2, 8, 32] };

    for &ck in ckpt_sizes {
        let data = vec![0x55u8; (ck * MB) as usize];
        let mut group = BenchGroup::start(&format!("fig7: {ck} MB checkpoint"));
        let path = dir.join("bench.bin");
        group.bench_bytes("baseline buffered 64KB chunks", data.len() as u64, || {
            write_file(&IoConfig::baseline().microbench(), &path, &data).unwrap();
        });
        for &buf in buf_sizes {
            for (name, kind) in
                [("single", EngineKind::DirectSingle), ("double", EngineKind::DirectDouble)]
            {
                let cfg = IoConfig::with_kind(kind)
                    .with_buf_size((buf * MB) as usize)
                    .microbench();
                group.bench_bytes(
                    &format!("direct-{name} io_buf={buf}MB"),
                    data.len() as u64,
                    || {
                        write_file(&cfg, &path, &data).unwrap();
                    },
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
