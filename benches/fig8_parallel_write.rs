//! Bench: Figure 8 family — parallel checkpoint writes.
//!
//! Part 1 (real): the CheckpointEngine writing one store with 1/2/4
//! parallel writer threads on local disk (single-vCPU container: this
//! measures protocol overhead, not device parallelism).
//! Part 2 (simulated): the paper-scale Replica-vs-Socket sweep.

use std::collections::BTreeMap;

use fastpersist::benchkit::BenchGroup;
use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::topology::RankPlacement;
use fastpersist::io::engine::IoConfig;
use fastpersist::tensor::{DType, Tensor, TensorStore};

fn group_of(n: usize) -> Vec<RankPlacement> {
    (0..n)
        .map(|r| RankPlacement { rank: r, node: 0, socket: r % 2, local_gpu: r })
        .collect()
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let size = if fast { 32 << 20 } else { 128 << 20 };
    let dir = fastpersist::io::engine::scratch_dir("bench-fig8").unwrap();

    let mut store = TensorStore::new();
    store
        .push(Tensor::new("payload", DType::U8, vec![size], vec![0xa5u8; size]).unwrap())
        .unwrap();

    let mut group = BenchGroup::start(&format!(
        "fig8: parallel checkpoint write ({} MiB store, real disk)",
        size >> 20
    ));
    for writers in [1usize, 2, 4] {
        let engine =
            CheckpointEngine::new(IoConfig::fastpersist().microbench(), WriterStrategy::AllReplicas);
        let g = group_of(writers);
        let d = dir.join(format!("w{writers}"));
        group.bench_bytes(&format!("{writers} writers"), size as u64, || {
            engine.write(&store, BTreeMap::new(), &d, &g).unwrap();
        });
    }

    println!("\nfig8 paper-scale simulation:");
    fastpersist::figures::fig8::run().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
