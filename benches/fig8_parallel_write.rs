//! Bench: Figure 8 family — parallel checkpoint writes.
//!
//! Part 1 (real): the CheckpointEngine writing one store with 1/2/4
//! parallel writer threads on local disk (single-vCPU container: this
//! measures protocol overhead, not device parallelism).
//! Part 2 (real): device fan-out — the same store at a fixed writer
//! count striped across 1/2/4 `DeviceMap` mount points (simulated SSDs;
//! on one physical disk this measures the routing/striping overhead,
//! on real multi-SSD hosts point FASTPERSIST_SCRATCH at one mount and
//! the device roots at the others).
//! Part 3 (simulated): the paper-scale Replica-vs-Socket sweep.
//!
//! Emits `BENCH_fig8.json` (benchkit JSON) for trajectory tracking.

use std::collections::BTreeMap;
use std::sync::Arc;

use fastpersist::benchkit::{write_bench_json, BenchGroup};
use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::topology::RankPlacement;
use fastpersist::io::device::DeviceMap;
use fastpersist::io::engine::{IoBackend, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};

fn group_of(n: usize) -> Vec<RankPlacement> {
    (0..n)
        .map(|r| RankPlacement { rank: r, node: 0, socket: r % 2, local_gpu: r })
        .collect()
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let size = if fast { 32 << 20 } else { 128 << 20 };
    let dir = fastpersist::io::engine::scratch_dir("bench-fig8").unwrap();

    let mut store = TensorStore::new();
    store
        .push(Tensor::new("payload", DType::U8, vec![size], vec![0xa5u8; size]).unwrap())
        .unwrap();

    // Part 1: writer-count sweep. ONE persistent runtime serves every
    // configuration — engines are constructed outside the timed region
    // and staging buffers are recycled across all iterations.
    let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        ..IoRuntimeConfig::default()
    }));
    let mut writers_group = BenchGroup::start(&format!(
        "fig8: parallel checkpoint write ({} MiB store, real disk)",
        size >> 20
    ));
    for writers in [1usize, 2, 4] {
        let engine =
            CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas);
        let g = group_of(writers);
        let d = dir.join(format!("w{writers}"));
        writers_group.bench_bytes(&format!("{writers} writers"), size as u64, || {
            engine.write(&store, BTreeMap::new(), &d, &g).unwrap();
        });
    }
    let allocs = runtime.staging().allocations();
    println!(
        "  staging pool: {} buffers allocated total, {} checkouts (reuse across all runs)",
        allocs,
        runtime.staging().acquires()
    );

    // Part 2: device fan-out at a fixed writer count.
    let mut devices_group = BenchGroup::start(&format!(
        "fig8: device fan-out ({} MiB store, 4 writers, simulated SSD roots)",
        size >> 20
    ));
    for ndev in [1usize, 2, 4] {
        let devmap = DeviceMap::simulated(ndev, &dir.join(format!("ssds{ndev}"))).unwrap();
        let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            devices: devmap,
            ..IoRuntimeConfig::default()
        }));
        let engine = CheckpointEngine::with_runtime(rt, WriterStrategy::AllReplicas);
        let g = group_of(4);
        let d = dir.join(format!("dev{ndev}"));
        devices_group.bench_bytes(&format!("{ndev} devices"), size as u64, || {
            engine.write(&store, BTreeMap::new(), &d, &g).unwrap();
        });
    }

    // Part 2b: durable direct-path counters for a device-striped write —
    // proves whether O_DIRECT actually engaged per device (or the probed
    // fallback did) and what submission-queue depth the drains reached.
    let mut counters_group =
        BenchGroup::start("fig8: direct/bounce/queue-depth counters (durable, 2 devices)");
    {
        let devmap = DeviceMap::simulated(2, &dir.join("ssds-direct")).unwrap();
        let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist(), // durable, try_o_direct on
            devices: devmap,
            ..IoRuntimeConfig::default()
        }));
        let engine = CheckpointEngine::with_runtime(rt, WriterStrategy::AllReplicas);
        let g = group_of(4);
        let d = dir.join("direct-counters");
        let out = engine.write(&store, BTreeMap::new(), &d, &g).unwrap();
        let direct_bytes: u64 = out.stats.iter().map(|s| s.direct_bytes).sum();
        let qd_max = out.stats.iter().map(|s| s.queue_depth_max).max().unwrap_or(0);
        counters_group.bench_bytes(
            &format!(
                "4 writers x 2 devices direct_bytes={direct_bytes} direct_extents={} \
                 bounce_bytes={} qd_max={qd_max}",
                out.direct_extents(),
                out.bounce_bytes(),
            ),
            size as u64,
            || {
                engine.write(&store, BTreeMap::new(), &d, &g).unwrap();
            },
        );
    }

    // Part 2c: submission-backend sweep — per-extent sync vs batched
    // ring vs auto-probed, durable config so the trailing fsync rides
    // the submission path under test. Row names carry the resolved
    // backend and the ring counters: on tmpfs/9p `ring` and `auto` fall
    // back to sync (resolved=sync, batched_submissions=0) and the rows
    // still emit, so trajectories stay comparable across environments.
    let mut backend_group = BenchGroup::start(&format!(
        "fig8: submission backend sweep ({} MiB store, durable, 4 writers)",
        size >> 20
    ));
    for (backend, tag) in
        [(IoBackend::Sync, "sync"), (IoBackend::Ring, "ring"), (IoBackend::Auto, "auto")]
    {
        let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig { backend, ..IoConfig::fastpersist() },
            ..IoRuntimeConfig::default()
        }));
        let engine = CheckpointEngine::with_runtime(Arc::clone(&rt), WriterStrategy::AllReplicas);
        let g = group_of(4);
        let d = dir.join(format!("backend-{tag}"));
        let out = engine.write(&store, BTreeMap::new(), &d, &g).unwrap();
        backend_group.bench_bytes(
            &format!(
                "backend={tag} resolved={} batched_submissions={} sqes_max={} reaped={}",
                rt.submit_backend_name(&d),
                out.batched_submissions(),
                out.sqes_per_submit_max(),
                out.completions_reaped(),
            ),
            size as u64,
            || {
                engine.write(&store, BTreeMap::new(), &d, &g).unwrap();
            },
        );
    }

    let _ = write_bench_json(
        "fig8",
        &[&writers_group, &devices_group, &counters_group, &backend_group],
    );

    println!("\nfig8 paper-scale simulation:");
    fastpersist::figures::fig8::run().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
