//! Bench: Figure 10 — sparse (MoE) checkpoint + E2E speedups
//! (simulator sweep + table regeneration).

use fastpersist::benchkit::BenchGroup;

fn main() {
    let mut group = BenchGroup::start("fig10: MoE sweep (simulated)");
    group.bench("full fig10 sweep", || {
        let rows = fastpersist::figures::fig10::compute().unwrap();
        assert_eq!(rows.len(), 4);
        std::hint::black_box(&rows);
    });
    fastpersist::figures::fig10::run().unwrap();
}
