//! Bench: Figure 11 — pipelined checkpointing, measured on REAL
//! training (tiny GPT via PJRT) across gradient-accumulation settings,
//! plus the paper-scale simulated sweep.
//!
//! Real part: per-iteration wall time with sync vs pipelined
//! checkpointing at GAS ∈ {1, 4, 16}. Higher GAS → more F+B per
//! optimizer step → more room to hide the write (§2.1.2/§5.6.1).

use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::io::engine::IoConfig;
use fastpersist::runtime::artifacts::ArtifactManifest;
use fastpersist::training::looper::{CkptRunMode, Trainer, TrainerConfig};
use fastpersist::util::table::Table;

fn run_mode(
    manifest: &ArtifactManifest,
    mode: CkptRunMode,
    ga: u64,
    dir: std::path::PathBuf,
) -> (f64, f64) {
    let cfg = TrainerConfig {
        model: "tiny".into(),
        steps: 8,
        ckpt_every: 1,
        ckpt_dir: dir,
        mode,
        strategy: WriterStrategy::AllReplicas,
        io: IoConfig::fastpersist().microbench(),
        devices: fastpersist::io::device::DeviceMap::single(),
        dp_writers: 2,
        grad_accum: ga,
        seed: 0,
        keep_last: 1,
        log_every: 0,
    };
    let mut t = Trainer::new(manifest, cfg).unwrap();
    t.run().unwrap();
    (t.recorder.summary("iter_s").p50, t.total_stall() / 8.0)
}

fn main() {
    let manifest = match ArtifactManifest::load(&ArtifactManifest::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("skipping real part ({e}); simulated sweep only");
            fastpersist::figures::fig11::run().unwrap();
            return;
        }
    };
    let dir = fastpersist::io::engine::scratch_dir("bench-fig11").unwrap();
    println!("\n=== fig11 (real): tiny GPT, per-iteration ckpt, sync vs pipelined ===");
    let mut table = Table::new(vec![
        "GAS", "sync iter p50 (ms)", "pipe iter p50 (ms)", "sync stall/iter (ms)",
        "pipe stall/iter (ms)",
    ]);
    for ga in [1u64, 4, 16] {
        let (sync_iter, sync_stall) =
            run_mode(&manifest, CkptRunMode::Sync, ga, dir.join(format!("s{ga}")));
        let (pipe_iter, pipe_stall) =
            run_mode(&manifest, CkptRunMode::Pipelined, ga, dir.join(format!("p{ga}")));
        table.row(vec![
            ga.to_string(),
            format!("{:.1}", sync_iter * 1e3),
            format!("{:.1}", pipe_iter * 1e3),
            format!("{:.2}", sync_stall * 1e3),
            format!("{:.2}", pipe_stall * 1e3),
        ]);
    }
    println!("{}", table.render());
    println!("(single-vCPU container: pipelining removes the *stall*; wall-clock");
    println!(" gains require a second core — see EXPERIMENTS.md)");

    fastpersist::figures::fig11::run().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
