//! Bench: Figure 11 — pipelined checkpointing, measured on REAL
//! training (tiny GPT via PJRT) across gradient-accumulation settings,
//! plus the paper-scale simulated sweep.
//!
//! Real part: per-iteration wall time with sync vs pipelined
//! checkpointing at GAS ∈ {1, 4, 16}. Higher GAS → more F+B per
//! optimizer step → more room to hide the write (§2.1.2/§5.6.1).
//!
//! All trainer runs submit into **one shared [`IoRuntime`]** (PR 1's
//! persistent staging pool + writer pool), so back-to-back modes reuse
//! the same staging buffers and writer threads — steady-state, not
//! cold-start, numbers. Emits `BENCH_fig11.json` (benchkit JSON) for
//! trajectory tracking.

use std::sync::Arc;

use fastpersist::benchkit::{write_bench_json, BenchGroup, BenchResult};
use fastpersist::checkpoint::delta::CheckpointStrategy;
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::io::engine::IoConfig;
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::runtime::artifacts::ArtifactManifest;
use fastpersist::training::looper::{CkptRunMode, Trainer, TrainerConfig};
use fastpersist::util::stats::Summary;
use fastpersist::util::table::Table;

fn run_mode(
    manifest: &ArtifactManifest,
    runtime: &Arc<IoRuntime>,
    mode: CkptRunMode,
    ga: u64,
    dir: std::path::PathBuf,
) -> (Vec<f64>, f64) {
    let cfg = TrainerConfig {
        model: "tiny".into(),
        steps: 8,
        ckpt_every: 1,
        ckpt_dir: dir,
        mode,
        strategy: WriterStrategy::AllReplicas,
        ckpt_strategy: CheckpointStrategy::Full,
        segment_bytes: 64 << 20,
        io: IoConfig::fastpersist().microbench(),
        devices: fastpersist::io::device::DeviceMap::single(),
        dp_writers: 2,
        grad_accum: ga,
        seed: 0,
        keep_last: 1,
        gc_occupancy: 0.5,
        log_every: 0,
    };
    let mut t = Trainer::new_with_runtime(manifest, cfg, Arc::clone(runtime)).unwrap();
    t.run().unwrap();
    (t.recorder.samples("iter_s").to_vec(), t.total_stall() / 8.0)
}

fn main() {
    let manifest = match ArtifactManifest::load(&ArtifactManifest::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("skipping real part ({e}); simulated sweep only");
            fastpersist::figures::fig11::run().unwrap();
            return;
        }
    };
    let dir = fastpersist::io::engine::scratch_dir("bench-fig11").unwrap();
    // One persistent I/O runtime for every mode/GAS combination below:
    // staging buffers are allocated once, writer threads live across
    // all runs (the PR 1 steady-state regime).
    let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        ..IoRuntimeConfig::default()
    }));
    runtime.staging().prewarm();
    println!("\n=== fig11 (real): tiny GPT, per-iteration ckpt, sync vs pipelined ===");
    let mut group = BenchGroup::new("fig11: sync vs pipelined iteration time (shared runtime)");
    let mut table = Table::new(vec![
        "GAS", "sync iter p50 (ms)", "pipe iter p50 (ms)", "sync stall/iter (ms)",
        "pipe stall/iter (ms)",
    ]);
    for ga in [1u64, 4, 16] {
        let (sync_iters, sync_stall) = run_mode(
            &manifest,
            &runtime,
            CkptRunMode::Sync,
            ga,
            dir.join(format!("s{ga}")),
        );
        let (pipe_iters, pipe_stall) = run_mode(
            &manifest,
            &runtime,
            CkptRunMode::Pipelined,
            ga,
            dir.join(format!("p{ga}")),
        );
        let sync = Summary::of(&sync_iters);
        let pipe = Summary::of(&pipe_iters);
        table.row(vec![
            ga.to_string(),
            format!("{:.1}", sync.p50 * 1e3),
            format!("{:.1}", pipe.p50 * 1e3),
            format!("{:.2}", sync_stall * 1e3),
            format!("{:.2}", pipe_stall * 1e3),
        ]);
        group.results.push(BenchResult {
            name: format!("iter/sync ga{ga}"),
            summary: sync,
            bytes_per_iter: None,
        });
        group.results.push(BenchResult {
            name: format!("iter/pipelined ga{ga}"),
            summary: pipe,
            bytes_per_iter: None,
        });
    }
    println!("{}", table.render());
    let allocs = runtime.staging().allocations();
    println!(
        "(shared runtime: {} staging allocations across all {} runs; single-vCPU",
        allocs, 6
    );
    println!(" containers show pipelining as removed *stall* — see ARCHITECTURE.md §1)");
    let _ = write_bench_json("fig11", &[&group]);

    fastpersist::figures::fig11::run().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
