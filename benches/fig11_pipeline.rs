//! Bench: Figure 11 — checkpoint/compute overlap, eager vs pipelined vs
//! lazy, full vs delta.
//!
//! Two measured parts plus the paper-scale simulated sweep:
//!
//! * **Synthetic overlap harness** (always runs, no AOT artifacts
//!   needed): a mutating synthetic state checkpointed per "iteration"
//!   (a calibrated busy-wait compute phase), across eager-sync,
//!   pipelined, and lazy capture/flush modes. Every row reports
//!   per-step `stall_s` (trainer-side blocked time: write latency for
//!   eager, `wait_previous` for pipelined, capture copy + staged
//!   backpressure for lazy) and `drain_s` (flush work that ran
//!   concurrently with compute) — the ledger proving the overlap.
//! * **Real trainer sweep** (when artifacts are present): tiny GPT via
//!   PJRT at GAS ∈ {1, 4, 16}, sync vs pipelined vs lazy. Higher GAS →
//!   more F+B per optimizer step → more room to hide the write
//!   (§2.1.2/§5.6.1).
//!
//! All runs submit into **one shared [`IoRuntime`]** (persistent
//! staging pool + writer pool), so back-to-back modes reuse the same
//! staging buffers and writer threads — steady-state, not cold-start,
//! numbers. Emits `BENCH_fig11.json` (benchkit JSON) for trajectory
//! tracking; CI validates its schema (`tools/check_bench_schema.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastpersist::benchkit::{write_bench_json, BenchGroup, BenchResult};
use fastpersist::checkpoint::delta::{CheckpointStrategy, DeltaCheckpointer, DeltaConfig};
use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::lazy::{LazyCheckpointer, LazyConfig};
use fastpersist::checkpoint::pipeline::PipelinedCheckpointer;
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::topology::RankPlacement;
use fastpersist::io::engine::IoConfig;
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::runtime::artifacts::ArtifactManifest;
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::training::looper::{CkptRunMode, Trainer, TrainerConfig};
use fastpersist::util::json::Json;
use fastpersist::util::stats::Summary;
use fastpersist::util::table::Table;

fn group_of(writers: usize) -> Vec<RankPlacement> {
    (0..writers)
        .map(|r| RankPlacement { rank: r, node: 0, socket: r % 2, local_gpu: r })
        .collect()
}

fn synthetic_store(nbytes: usize) -> TensorStore {
    let mut s = TensorStore::new();
    s.push(Tensor::new("w", DType::U8, vec![nbytes], vec![0x42u8; nbytes]).unwrap())
        .unwrap();
    s
}

/// Touch ~10% of the state (middle slice, step-dependent pattern) so
/// delta flavors have real dirty chunks per step.
fn mutate(store: &mut TensorStore, step: u64) {
    let mut data = store.get("w").unwrap().data.as_ref().clone();
    let n = data.len();
    let (a, b) = (n * 45 / 100, n * 55 / 100);
    for (i, x) in data[a..b].iter_mut().enumerate() {
        *x ^= (step as u8).wrapping_add(i as u8) | 1;
    }
    store.update("w", data).unwrap();
}

fn extras_for(step: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Int(step as i64));
    m
}

/// Stand-in for the F+B compute phase: spin for `d` so the flush
/// helper has real wall-clock to overlap with.
fn busy_compute(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// One synthetic checkpointing flavor wired to the shared runtime.
enum Flavor {
    SyncFull(CheckpointEngine, Vec<RankPlacement>),
    Pipelined(PipelinedCheckpointer),
    Lazy(LazyCheckpointer),
}

struct SynthReport {
    iters: Vec<f64>,
    /// Trainer-side blocked seconds across all steps.
    stall_total: f64,
    /// Helper-side flush seconds that ran concurrently with compute.
    drain_total: f64,
}

fn run_synthetic(
    runtime: &Arc<IoRuntime>,
    flavor_name: &str,
    dir: &Path,
    steps: u64,
    nbytes: usize,
    compute: Duration,
) -> SynthReport {
    let dcfg = DeltaConfig { chunk_size: 64 << 10, ..DeltaConfig::default() };
    let lcfg = LazyConfig { staging_bytes: 64 << 20, buf_size: 4 << 20, max_generations: 2 };
    let group = group_of(2);
    let mut flavor = match flavor_name {
        "sync-full" => Flavor::SyncFull(
            CheckpointEngine::with_runtime(Arc::clone(runtime), WriterStrategy::AllReplicas),
            group,
        ),
        "pipelined-full" => Flavor::Pipelined(PipelinedCheckpointer::new(
            CheckpointEngine::with_runtime(Arc::clone(runtime), WriterStrategy::AllReplicas),
            group,
        )),
        "pipelined-delta" => Flavor::Pipelined(PipelinedCheckpointer::delta(
            DeltaCheckpointer::new(Arc::clone(runtime), dcfg),
        )),
        "lazy-full" => Flavor::Lazy(LazyCheckpointer::full(
            CheckpointEngine::with_runtime(Arc::clone(runtime), WriterStrategy::AllReplicas),
            group,
            lcfg,
        )),
        "lazy-delta" => Flavor::Lazy(LazyCheckpointer::delta(
            DeltaCheckpointer::new(Arc::clone(runtime), dcfg),
            lcfg,
        )),
        other => panic!("unknown flavor {other}"),
    };
    let mut store = synthetic_store(nbytes);
    let mut iters = Vec::new();
    let mut stall_total = 0.0f64;
    for step in 1..=steps {
        let it = Instant::now();
        busy_compute(compute);
        mutate(&mut store, step);
        let sdir = dir.join(format!("step-{step:08}"));
        let extras = extras_for(step);
        match &mut flavor {
            Flavor::SyncFull(engine, group) => {
                let t = Instant::now();
                engine.write(&store, extras, &sdir, group).unwrap();
                stall_total += t.elapsed().as_secs_f64();
            }
            Flavor::Pipelined(pipe) => {
                let t = Instant::now();
                pipe.wait_previous().unwrap();
                stall_total += t.elapsed().as_secs_f64();
                pipe.request(&store, extras, sdir).unwrap();
            }
            Flavor::Lazy(lz) => {
                lz.poll_completed().unwrap();
                let cs = lz.capture(&store, extras, sdir).unwrap();
                stall_total += (cs.stall + cs.copy).as_secs_f64();
            }
        }
        iters.push(it.elapsed().as_secs_f64());
    }
    // Shutdown drain (outside the steady-state per-step stall): collect
    // the concurrent-flush ledger.
    let drain_total = match flavor {
        Flavor::SyncFull(..) => 0.0,
        Flavor::Pipelined(mut pipe) => {
            pipe.wait_previous().unwrap();
            pipe.completed.iter().map(|o| o.latency.as_secs_f64()).sum()
        }
        Flavor::Lazy(mut lz) => {
            lz.wait_all().unwrap();
            lz.completed.iter().map(|o| o.drain.as_secs_f64()).sum()
        }
    };
    SynthReport { iters, stall_total, drain_total }
}

fn synthetic_part(runtime: &Arc<IoRuntime>, dir: &Path, fast: bool) -> BenchGroup {
    let (steps, nbytes, compute) = if fast {
        (4u64, 2usize << 20, Duration::from_millis(10))
    } else {
        (8u64, 4usize << 20, Duration::from_millis(20))
    };
    println!(
        "\n=== fig11 (synthetic): {} steps x {} MiB state, {} ms compute/step ===",
        steps,
        nbytes >> 20,
        compute.as_millis()
    );
    let mut group =
        BenchGroup::new("fig11: per-step stall vs concurrent drain (synthetic, shared runtime)");
    let mut table = Table::new(vec![
        "mode", "iter p50 (ms)", "stall/step (ms)", "drain/step (ms)", "stall %",
    ]);
    for flavor in ["sync-full", "pipelined-full", "pipelined-delta", "lazy-full", "lazy-delta"] {
        let d = dir.join(flavor);
        let rep = run_synthetic(runtime, flavor, &d, steps, nbytes, compute);
        let summary = Summary::of(&rep.iters);
        let stall_s = rep.stall_total / steps as f64;
        let drain_s = rep.drain_total / steps as f64;
        let iter_total: f64 = rep.iters.iter().sum();
        let stall_frac = if iter_total > 0.0 { rep.stall_total / iter_total } else { 0.0 };
        table.row(vec![
            flavor.to_string(),
            format!("{:.1}", summary.p50 * 1e3),
            format!("{:.2}", stall_s * 1e3),
            format!("{:.2}", drain_s * 1e3),
            format!("{:.1}%", stall_frac * 100.0),
        ]);
        let r = BenchResult {
            name: format!("synthetic iter/{flavor}"),
            summary,
            bytes_per_iter: Some(nbytes as u64),
            extras: Vec::new(),
        }
        .with_extra("stall_s", stall_s)
        .with_extra("drain_s", drain_s)
        .with_extra("stall_frac", stall_frac);
        group.results.push(r);
        if flavor == "lazy-delta" {
            println!(
                "  lazy-delta stall overhead: {:.2}% of step time (target < 5%) — {}",
                stall_frac * 100.0,
                if stall_frac < 0.05 { "ok" } else { "OVER" }
            );
        }
        let _ = std::fs::remove_dir_all(&d);
    }
    println!("{}", table.render());
    // Per-lane drain counters: the flush traffic the modes above pushed
    // through the shared runtime's submission lanes.
    let lanes = runtime.drain_lane_stats();
    let submitted: u64 = lanes.iter().map(|l| l.submissions).sum();
    if submitted > 0 {
        let busy: Vec<f64> = lanes.iter().map(|l| l.busy.as_secs_f64()).collect();
        let max_queued = lanes.iter().map(|l| l.max_queued).max().unwrap_or(0);
        println!(
            "  drain lanes {}: {} submissions, max queued/lane {}",
            lanes.len(),
            submitted,
            max_queued
        );
        group.results.push(
            BenchResult {
                name: format!(
                    "drain-lane busy ({} lanes, {} submissions, max queued {})",
                    lanes.len(),
                    submitted,
                    max_queued
                ),
                summary: Summary::of(&busy),
                bytes_per_iter: None,
                extras: Vec::new(),
            }
            .with_extra("submissions", submitted as f64)
            .with_extra("max_queued", max_queued as f64),
        );
    }
    group
}

fn run_mode(
    manifest: &ArtifactManifest,
    runtime: &Arc<IoRuntime>,
    mode: CkptRunMode,
    ga: u64,
    dir: PathBuf,
) -> (Vec<f64>, f64, f64) {
    let steps = 8u64;
    let cfg = TrainerConfig {
        model: "tiny".into(),
        steps,
        ckpt_every: 1,
        ckpt_dir: dir,
        mode,
        strategy: WriterStrategy::AllReplicas,
        ckpt_strategy: CheckpointStrategy::Full,
        segment_bytes: 64 << 20,
        ckpt_codec: fastpersist::checkpoint::codec::CodecKind::None,
        io: IoConfig::fastpersist().microbench(),
        devices: fastpersist::io::device::DeviceMap::single(),
        dp_writers: 2,
        grad_accum: ga,
        seed: 0,
        keep_last: 1,
        lazy_staging_bytes: 256 << 20,
        lazy_max_generations: 2,
        gc_occupancy: 0.5,
        log_every: 0,
    };
    let mut t = Trainer::new_with_runtime(manifest, cfg, Arc::clone(runtime)).unwrap();
    t.run().unwrap();
    (
        t.recorder.samples("iter_s").to_vec(),
        t.total_stall() / steps as f64,
        t.recorder.total("drain_s") / steps as f64,
    )
}

fn real_part(manifest: &ArtifactManifest, runtime: &Arc<IoRuntime>, dir: &Path) -> BenchGroup {
    println!("\n=== fig11 (real): tiny GPT, per-iteration ckpt, sync vs pipelined vs lazy ===");
    let mut group =
        BenchGroup::new("fig11: sync vs pipelined vs lazy iteration time (shared runtime)");
    let mut table = Table::new(vec![
        "GAS",
        "sync iter p50 (ms)",
        "pipe iter p50 (ms)",
        "lazy iter p50 (ms)",
        "sync stall (ms)",
        "pipe stall (ms)",
        "lazy stall (ms)",
        "lazy drain (ms)",
    ]);
    for ga in [1u64, 4, 16] {
        let (sync_iters, sync_stall, _) =
            run_mode(manifest, runtime, CkptRunMode::Sync, ga, dir.join(format!("s{ga}")));
        let (pipe_iters, pipe_stall, _) =
            run_mode(manifest, runtime, CkptRunMode::Pipelined, ga, dir.join(format!("p{ga}")));
        let (lazy_iters, lazy_stall, lazy_drain) =
            run_mode(manifest, runtime, CkptRunMode::Lazy, ga, dir.join(format!("l{ga}")));
        let sync = Summary::of(&sync_iters);
        let pipe = Summary::of(&pipe_iters);
        let lazy = Summary::of(&lazy_iters);
        table.row(vec![
            ga.to_string(),
            format!("{:.1}", sync.p50 * 1e3),
            format!("{:.1}", pipe.p50 * 1e3),
            format!("{:.1}", lazy.p50 * 1e3),
            format!("{:.2}", sync_stall * 1e3),
            format!("{:.2}", pipe_stall * 1e3),
            format!("{:.2}", lazy_stall * 1e3),
            format!("{:.2}", lazy_drain * 1e3),
        ]);
        group.results.push(
            BenchResult {
                name: format!("iter/sync ga{ga}"),
                summary: sync,
                bytes_per_iter: None,
                extras: Vec::new(),
            }
            .with_extra("stall_s", sync_stall)
            .with_extra("drain_s", 0.0),
        );
        group.results.push(
            BenchResult {
                name: format!("iter/pipelined ga{ga}"),
                summary: pipe,
                bytes_per_iter: None,
                extras: Vec::new(),
            }
            .with_extra("stall_s", pipe_stall),
        );
        group.results.push(
            BenchResult {
                name: format!("iter/lazy ga{ga}"),
                summary: lazy,
                bytes_per_iter: None,
                extras: Vec::new(),
            }
            .with_extra("stall_s", lazy_stall)
            .with_extra("drain_s", lazy_drain),
        );
    }
    println!("{}", table.render());
    let allocs = runtime.staging().allocations();
    println!("(shared runtime: {allocs} staging allocations across all 9 runs; single-vCPU");
    println!(" containers show pipelining as removed *stall* — see ARCHITECTURE.md §1)");
    group
}

fn main() {
    let fast = std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1");
    let dir = fastpersist::io::engine::scratch_dir("bench-fig11").unwrap();
    // One persistent I/O runtime for every part below: staging buffers
    // are allocated once, writer threads live across all runs.
    let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        ..IoRuntimeConfig::default()
    }));
    runtime.staging().prewarm();

    let synth = synthetic_part(&runtime, &dir.join("synthetic"), fast);

    let real = match ArtifactManifest::load(&ArtifactManifest::default_dir()) {
        Ok(manifest) => Some(real_part(&manifest, &runtime, &dir)),
        Err(e) => {
            println!("(artifacts not available: {e}; synthetic part only)");
            None
        }
    };

    let mut groups: Vec<&BenchGroup> = vec![&synth];
    if let Some(g) = real.as_ref() {
        groups.push(g);
    }
    let _ = write_bench_json("fig11", &groups);

    fastpersist::figures::fig11::run().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
