//! Bench: Figure 9 — dense-model checkpoint + E2E speedups at up to
//! 128 GPUs (simulator sweep; also times the sweep itself so simulator
//! regressions are caught).

use fastpersist::benchkit::BenchGroup;

fn main() {
    let mut group = BenchGroup::start("fig9: dense-model sweep (simulated)");
    group.bench("full fig9 sweep", || {
        let rows = fastpersist::figures::fig9::compute().unwrap();
        assert!(!rows.is_empty());
        std::hint::black_box(&rows);
    });
    fastpersist::figures::fig9::run().unwrap();
}
