//! Bench: Figure 12 — projection to DP=128 (simulator) + timing of the
//! projection sweep.

use fastpersist::benchkit::BenchGroup;

fn main() {
    let mut group = BenchGroup::start("fig12: DP projection sweep (simulated)");
    group.bench("full fig12 sweep", || {
        let sweep = fastpersist::sim::project::fig12_sweep().unwrap();
        assert_eq!(sweep.len(), 12);
        std::hint::black_box(&sweep);
    });
    fastpersist::figures::fig12::run().unwrap();
}
